//! Attention mask kinds, threaded end to end (DESIGN.md §6).
//!
//! A mask names which `(query row i, key j)` pairs participate in the
//! softmax.  Both non-trivial kinds are *column-prefix* masks: for every
//! query row the valid keys form a prefix `j < valid_keys(i)` of the key
//! sequence.  That structural fact is what makes the tile-skipping
//! schedule exact — a tile whose keys all fall outside every covered
//! row's prefix can be skipped without touching the online-softmax
//! state, and a partially covered tile needs only an element-wise mask
//! pass over its invalid lanes — see
//! [`flash_forward_masked`](crate::numerics::reference::flash_forward_masked)
//! and the legality argument in DESIGN.md §6.

use std::fmt;

use anyhow::bail;

/// Which `(query, key)` pairs an attention operator may attend.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum MaskKind {
    /// Unmasked square attention (the original behavior).
    #[default]
    None,
    /// Causal SDPA: query row `i` attends keys `j <= i` — transformer
    /// prefill.  Skips the upper-triangular tiles entirely (≈2× fewer
    /// tile-cycles at large L, [`crate::perfmodel::fsa_flash_perf_masked`]).
    Causal,
    /// Only the first `valid` keys are real; the rest are zero padding
    /// (stamped by [`AttentionRequest::padded`], which makes bucket
    /// padding *exact* instead of the old residual-weight approximation).
    ///
    /// [`AttentionRequest::padded`]: crate::coordinator::request::AttentionRequest::padded
    PaddingKeys { valid: usize },
}

/// How a mask covers one `rows × cols` tile of the score matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TileCoverage {
    /// Every element valid: the tile runs the unmasked schedule.
    Full,
    /// Mixed: the tile runs with an element-wise mask pass.
    Partial,
    /// No element valid: the tile is skipped entirely (exact — it would
    /// contribute nothing to any row's online-softmax state).
    Empty,
}

impl MaskKind {
    /// Whether query row `i` may attend key `j`.
    pub fn allows(&self, i: usize, j: usize) -> bool {
        match self {
            MaskKind::None => true,
            MaskKind::Causal => j <= i,
            MaskKind::PaddingKeys { valid } => j < *valid,
        }
    }

    /// Number of valid keys of query row `i` over an `lk`-key sequence.
    /// Valid keys always form the prefix `0..valid_keys(i, lk)`.
    pub fn valid_keys(&self, i: usize, lk: usize) -> usize {
        match self {
            MaskKind::None => lk,
            MaskKind::Causal => (i + 1).min(lk),
            MaskKind::PaddingKeys { valid } => (*valid).min(lk),
        }
    }

    /// Classify the tile `[r0, r0+rows) × [c0, c0+cols)`.
    pub fn coverage(&self, r0: usize, rows: usize, c0: usize, cols: usize) -> TileCoverage {
        debug_assert!(rows >= 1 && cols >= 1);
        match self {
            MaskKind::None => TileCoverage::Full,
            MaskKind::Causal => {
                if c0 + cols <= r0 + 1 {
                    TileCoverage::Full // last key <= first row
                } else if c0 > r0 + rows - 1 {
                    TileCoverage::Empty // first key > last row
                } else {
                    TileCoverage::Partial // straddles the diagonal
                }
            }
            MaskKind::PaddingKeys { valid } => {
                if c0 + cols <= *valid {
                    TileCoverage::Full
                } else if c0 >= *valid {
                    TileCoverage::Empty
                } else {
                    TileCoverage::Partial
                }
            }
        }
    }

    /// True for [`MaskKind::None`] (the only kind the mask-free PJRT
    /// artifacts can execute).
    pub fn is_none(&self) -> bool {
        matches!(self, MaskKind::None)
    }
}

impl std::str::FromStr for MaskKind {
    type Err = anyhow::Error;

    /// `none | causal | padding:<valid>` — the last mostly for
    /// completeness; padding masks are normally stamped by
    /// `AttentionRequest::padded`, not configured.
    fn from_str(s: &str) -> crate::Result<MaskKind> {
        match s {
            "none" => Ok(MaskKind::None),
            "causal" => Ok(MaskKind::Causal),
            other => match other.strip_prefix("padding:").map(str::parse::<usize>) {
                Some(Ok(valid)) => Ok(MaskKind::PaddingKeys { valid }),
                _ => bail!("unknown mask {other:?} (try none|causal|padding:<valid>)"),
            },
        }
    }
}

impl fmt::Display for MaskKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MaskKind::None => f.write_str("none"),
            MaskKind::Causal => f.write_str("causal"),
            MaskKind::PaddingKeys { valid } => write!(f, "padding:{valid}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allows_and_valid_key_prefixes_agree() {
        for mask in [MaskKind::None, MaskKind::Causal, MaskKind::PaddingKeys { valid: 5 }] {
            for i in 0..8 {
                let vk = mask.valid_keys(i, 8);
                for j in 0..8 {
                    assert_eq!(mask.allows(i, j), j < vk, "{mask:?} i={i} j={j}");
                }
            }
        }
        assert_eq!(MaskKind::Causal.valid_keys(100, 8), 8, "clamped to lk");
        assert_eq!(MaskKind::PaddingKeys { valid: 0 }.valid_keys(3, 8), 0);
    }

    #[test]
    fn causal_tile_coverage_splits_at_the_diagonal() {
        let m = MaskKind::Causal;
        // 4x4 tiles on a 16x16 matrix: below-diagonal full, diagonal
        // partial, above-diagonal empty.
        for i in 0..4usize {
            for j in 0..4usize {
                let want = if j < i {
                    TileCoverage::Full
                } else if j == i {
                    TileCoverage::Partial
                } else {
                    TileCoverage::Empty
                };
                assert_eq!(m.coverage(i * 4, 4, j * 4, 4), want, "tile ({i},{j})");
            }
        }
        // A 1x1 tile exactly on the diagonal is fully valid.
        assert_eq!(m.coverage(3, 1, 3, 1), TileCoverage::Full);
        assert_eq!(m.coverage(3, 1, 4, 1), TileCoverage::Empty);
    }

    #[test]
    fn padding_tile_coverage_splits_at_the_boundary() {
        let m = MaskKind::PaddingKeys { valid: 100 };
        assert_eq!(m.coverage(0, 128, 0, 100), TileCoverage::Full);
        assert_eq!(m.coverage(0, 128, 0, 128), TileCoverage::Partial);
        assert_eq!(m.coverage(0, 128, 100, 28), TileCoverage::Empty);
        assert_eq!(m.coverage(0, 128, 128, 128), TileCoverage::Empty);
        assert_eq!(
            MaskKind::PaddingKeys { valid: 0 }.coverage(0, 8, 0, 8),
            TileCoverage::Empty
        );
        assert_eq!(MaskKind::None.coverage(0, 8, 0, 8), TileCoverage::Full);
    }

    #[test]
    fn parse_and_display_round_trip() {
        for (s, m) in [
            ("none", MaskKind::None),
            ("causal", MaskKind::Causal),
            ("padding:37", MaskKind::PaddingKeys { valid: 37 }),
        ] {
            assert_eq!(s.parse::<MaskKind>().unwrap(), m);
            assert_eq!(m.to_string(), s);
        }
        assert!("triangular".parse::<MaskKind>().is_err());
        assert!("padding:x".parse::<MaskKind>().is_err());
        assert!(MaskKind::None.is_none());
        assert!(!MaskKind::Causal.is_none());
        assert_eq!(MaskKind::default(), MaskKind::None);
    }
}
