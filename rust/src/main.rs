//! `fsa` — the leader binary: experiment reports, device inspection, and
//! the serving loop.  Run `fsa help` for the command list.

use std::path::PathBuf;

use fsa::cli::Args;
use fsa::config::RunConfig;
use fsa::coordinator::request::AttentionRequest;
use fsa::coordinator::Coordinator;
use fsa::experiments;
use fsa::kernel::{flash_attention_program, FlashLayout, FlashParams};
use fsa::numerics::SplitMix64;

const HELP: &str = "\
fsa — SystolicAttention / FSA reproduction

USAGE: fsa <command> [--flag value]...

Experiment commands (paper artifact regeneration):
  table1                       accelerator configurations
  fig1    [--seq 8192]         component active-time breakdown
  fig11   [--seqs 2048,..]     FLOPs/s utilization comparison
  fig12   [--segments 1,2,..]  exp2 PWL error sweep
  table2  [--seqs 2048,4096] [--artifacts DIR] [--seed N]
                               end-to-end accuracy via PJRT artifacts
  table3  [--n 128]            area breakdown
  cycles  [--sizes 4,8,16,32]  cycle-sim vs closed-form validation

Device / serving commands:
  disasm  [--seq 512 --d 128]  compile + disassemble the flash kernel
  serve   [--requests 16 --devices 2 --seq 512 --artifacts DIR]
          [--heads 1 --kv-heads 1 --backend pjrt|reference|sim|auto]
          [--mask none|causal --freq-ghz 1.5 --seq-shards 1]
          [--sim-max-seq 8192 --sim-batch-shards 8 --sim-prog-cache 256
           --array-size 128]
          [--max-batch-prefill-tokens 8192 --max-batch-total-tokens 65536
           --waiting-served-ratio 1.2]
          [--trace off|summary|full --metrics-json PATH]
                               boot the coordinator and serve a workload
                               (multi-head/GQA requests are sharded
                               per head across the device pool; --mask
                               causal serves exact causal prefill with
                               the tile-skipping schedule and needs
                               --backend reference|sim — the AOT
                               artifacts take no mask, and auto picks
                               PJRT whenever artifacts exist;
                               --seq-shards N additionally splits every
                               K/V into N sequence chunks merged exactly
                               at gather — long-context serving past one
                               device, reference|sim backends only;
                               --backend sim executes every shard on the
                               cycle-accurate machine, bitwise-equal to
                               reference, priced by MEASURED cycles —
                               O(L²) per shard, guarded by
                               --sim-max-seq; --sim-batch-shards N lets
                               N shards share one machine between
                               hazard fences (1 disables reuse);
                               --sim-prog-cache N caches N compiled ISA
                               programs per device, skipping per-shard
                               rebuilds without changing served bits or
                               measured cycles (0 disables);
                               --array-size shrinks the simulated array
                               for fast sim runs; the continuous
                               scheduler (DESIGN.md §10) caps each wave
                               at --max-batch-prefill-tokens prefill
                               tokens and live + admitted tokens at
                               --max-batch-total-tokens, and defers
                               fresh prefills while decode traffic runs
                               until waiting >= --waiting-served-ratio
                               x live tokens (0 disables deferral);
                               --trace records
                               request-path span events — summary keeps
                               per-kind counts, full adds a 4096-event
                               ring — without changing served bits;
                               --metrics-json writes the MetricsSnapshot
                               as JSON to PATH on shutdown: counters,
                               per-op-kind latency histograms incl.
                               TTFT/TPOT, queue depth, KV occupancy)
          [--decode-steps 0 --sessions 1 --kv-pages 4096
           --page-size 16 --eviction lru|none]
                               with --decode-steps > 0: decode-phase
                               serving — prefill --sessions sessions at
                               --seq, interleave that many decode steps
                               per session over the paged KV caches,
                               close, and report hit/miss/eviction
                               counters (backend reference|auto)
          [--prefix-cache on|off]
                               cross-session prefix caching (DESIGN.md
                               §11, off by default): prefills sharing a
                               byte-identical prefix with a live session
                               resume from the first uncovered row —
                               the response carries only the suffix
                               query rows (bitwise the cold run's) and
                               shared KV pages attach by refcount; needs
                               --backend reference|sim (the AOT
                               artifacts have no resumed kind); with
                               --decode-steps serving every session's
                               prompt opens with a shared half-prompt
                               system prefix so warm prefills resume
                               from live pages
  help                         this text
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> fsa::Result<()> {
    match args.command.as_str() {
        "table1" => println!("{}", experiments::table1_report()),
        "fig1" => {
            let seq = args.get("seq", 8192usize)?;
            println!("{}", experiments::fig1_report(seq));
        }
        "fig11" => {
            let seqs = args.get_list("seqs", &fsa::accel::paper_seq_lens())?;
            let d = args.get("d", 128usize)?;
            println!("{}", experiments::fig11_report(&seqs, d));
        }
        "fig12" => {
            let segs = args.get_list("segments", &[1, 2, 4, 8, 16, 32, 64])?;
            println!("{}", experiments::fig12_report(&segs));
        }
        "table2" => {
            let seqs = args.get_list("seqs", &[128, 512, 2048, 4096])?;
            let d = args.get("d", 128usize)?;
            let seed = args.get("seed", 0xF5Au64)?;
            let dir = PathBuf::from(args.flag("artifacts").unwrap_or("artifacts"));
            println!("{}", experiments::table2_report(&dir, &seqs, d, seed)?);
        }
        "table3" => {
            let n = args.get("n", 128usize)?;
            println!("{}", experiments::table3_report(n));
        }
        "cycles" => {
            let sizes = args.get_list("sizes", &[4, 8, 16, 32])?;
            println!("{}", experiments::cycles_report(&sizes));
        }
        "disasm" => {
            let seq = args.get("seq", 512usize)?;
            let d = args.get("d", 128usize)?;
            let p = FlashParams {
                seq_len: seq,
                d,
                spad_elems: (6 * d * d) as u32,
                accum_elems: (d * d + d) as u32,
            };
            let prog = flash_attention_program(&p, &FlashLayout::packed(&p))?;
            let (l, s, c) = prog.class_counts();
            println!(
                "FlashAttention program for seq={seq} d={d}: {} instructions \
                 ({l} loads, {s} stores, {c} compute)\n",
                prog.len()
            );
            println!("{}", prog.disasm());
        }
        "serve" => serve(args)?,
        _ => println!("{HELP}"),
    }
    Ok(())
}

fn serve(args: &Args) -> fsa::Result<()> {
    let mut cfg = RunConfig::default();
    cfg.devices = args.get("devices", cfg.devices)?;
    cfg.max_batch = args.get("max-batch", cfg.max_batch)?;
    cfg.artifacts_dir = args.flag("artifacts").unwrap_or("artifacts").to_string();
    cfg.backend = args.flag("backend").unwrap_or("pjrt").parse()?;
    cfg.num_heads = args.get("heads", cfg.num_heads)?;
    cfg.num_kv_heads = args.get("kv-heads", cfg.num_kv_heads)?;
    cfg.kv_cache_pages = args.get("kv-pages", cfg.kv_cache_pages)?;
    cfg.kv_page_size = args.get("page-size", cfg.kv_page_size)?;
    cfg.kv_eviction = args.flag("eviction").unwrap_or("lru").parse()?;
    if let Some(v) = args.flag("prefix-cache") {
        cfg.prefix_cache = fsa::config::parse_on_off(v)
            .ok_or_else(|| anyhow::anyhow!("--prefix-cache {v:?}: expected on|off"))?;
    }
    cfg.mask = args.flag("mask").unwrap_or("none").parse()?;
    cfg.freq_ghz = args.get("freq-ghz", cfg.freq_ghz)?;
    cfg.seq_shards = args.get("seq-shards", cfg.seq_shards)?;
    cfg.sim_max_seq = args.get("sim-max-seq", cfg.sim_max_seq)?;
    cfg.sim_batch_shards = args.get("sim-batch-shards", cfg.sim_batch_shards)?;
    cfg.sim_prog_cache = args.get("sim-prog-cache", cfg.sim_prog_cache)?;
    cfg.array_size = args.get("array-size", cfg.array_size)?;
    cfg.max_batch_prefill_tokens =
        args.get("max-batch-prefill-tokens", cfg.max_batch_prefill_tokens)?;
    cfg.max_batch_total_tokens =
        args.get("max-batch-total-tokens", cfg.max_batch_total_tokens)?;
    cfg.waiting_served_ratio = args.get("waiting-served-ratio", cfg.waiting_served_ratio)?;
    cfg.trace = args.flag("trace").unwrap_or("off").parse()?;
    let metrics_json = args.flag("metrics-json").map(PathBuf::from);
    let n_req = args.get("requests", 16usize)?;
    let seq = args.get("seq", 512usize)?;
    let d = args.get("d", 128usize)?;
    let decode_steps = args.get("decode-steps", 0usize)?;
    let n_sessions = args.get("sessions", 1usize)?;
    let (heads, kv_heads, mask) = (cfg.num_heads, cfg.num_kv_heads, cfg.mask);
    // Head-count invariants are validated once by Coordinator::start
    // (RunConfig::validate) before any request is constructed.

    println!(
        "booting coordinator: {} devices, backend {}, artifacts at {}, \
         mask {}, {:.2} GHz, {} seq shard(s), kv cache {} x {}-token pages ({}), \
         prefix cache {}",
        cfg.devices, cfg.backend, cfg.artifacts_dir, cfg.mask, cfg.freq_ghz,
        cfg.seq_shards, cfg.kv_cache_pages, cfg.kv_page_size, cfg.kv_eviction,
        if cfg.prefix_cache { "on" } else { "off" }
    );
    // With the prefix cache on, the decode-serving workload opens every
    // session with the same system prefix — half the prompt, rounded
    // down to whole KV pages — so prefills after the first actually
    // exercise the §11 match/resume path.
    let prefix_share =
        if cfg.prefix_cache { (seq / 2 / cfg.kv_page_size) * cfg.kv_page_size } else { 0 };
    let coord = Coordinator::start(cfg)?;
    if decode_steps > 0 {
        return serve_decode(
            coord, n_sessions, decode_steps, seq, d, heads, kv_heads, mask, prefix_share,
            metrics_json,
        );
    }
    let mut rng = SplitMix64::new(1);
    let mut pending = Vec::new();
    for id in 0..n_req as u64 {
        let q = rng.normal_matrix(heads * seq, d);
        let k = rng.normal_matrix(kv_heads * seq, d);
        let v = rng.normal_matrix(kv_heads * seq, d);
        pending.push(coord.submit(
            AttentionRequest::gqa(id, seq, d, heads, kv_heads, q, k, v).with_mask(mask),
        )?);
    }
    let mut ok = 0;
    let mut worst_util = f64::INFINITY;
    for rx in pending {
        let resp = rx.recv().map_err(|_| anyhow::anyhow!("worker dropped request"))?;
        if resp.output.is_ok() {
            ok += 1;
            worst_util = worst_util.min(resp.utilization);
        } else if let Err(e) = &resp.output {
            eprintln!("request {} failed: {e}", resp.id);
        }
    }
    println!(
        "{}/{} requests served ({heads} heads / {kv_heads} KV heads each)",
        ok, n_req
    );
    if ok > 0 {
        println!("worst whole-operator FLOPs/s utilization: {:.1}%", 100.0 * worst_util);
    }
    finish(coord, metrics_json.as_deref())
}

/// Common serve epilogue: the one-line counter summary, the trace
/// summary when tracing is on, the machine-readable snapshot when
/// `--metrics-json` asked for one, then shutdown.
fn finish(coord: Coordinator, metrics_json: Option<&std::path::Path>) -> fsa::Result<()> {
    println!("{}", coord.metrics.summary());
    if coord.tracer.enabled() {
        println!("{}", coord.tracer.summary());
    }
    if let Some(path) = metrics_json {
        let json = coord.metrics.snapshot().to_json().pretty();
        std::fs::write(path, &json)
            .map_err(|e| anyhow::anyhow!("writing metrics snapshot {}: {e}", path.display()))?;
        println!("metrics snapshot written to {}", path.display());
    }
    coord.shutdown();
    Ok(())
}

/// Decode-phase serving loop: prefill `n_sessions` sessions (causal
/// when `--mask causal` — the transformer-prefill regime), interleave
/// `steps` decode steps per session (round-robin, so device KV caches
/// juggle all sessions at once), close everything, and report the
/// cache counters.  With `prefix_share > 0` (`--prefix-cache on`)
/// every session's prompt opens with the same `prefix_share`-token
/// system prefix, so warm prefills resume from shared pages
/// (DESIGN.md §11).
#[allow(clippy::too_many_arguments)]
fn serve_decode(
    coord: Coordinator,
    n_sessions: usize,
    steps: usize,
    seq: usize,
    d: usize,
    heads: usize,
    kv_heads: usize,
    mask: fsa::mask::MaskKind,
    prefix_share: usize,
    metrics_json: Option<PathBuf>,
) -> fsa::Result<()> {
    let mut rng = SplitMix64::new(7);
    let mut id = 0u64;
    let mut next_id = || {
        id += 1;
        id
    };

    let (sys_k, sys_v) = if prefix_share > 0 {
        (rng.normal_matrix(kv_heads * seq, d), rng.normal_matrix(kv_heads * seq, d))
    } else {
        (Vec::new(), Vec::new())
    };
    // Overlay the shared system prefix onto a session's fresh K or V
    // (head-major `(kv_heads, seq, d)` layout).
    let share = |base: &[f32], mut fresh: Vec<f32>| -> Vec<f32> {
        if prefix_share == 0 {
            return fresh;
        }
        for h in 0..kv_heads {
            let at = h * seq * d;
            fresh[at..at + prefix_share * d].copy_from_slice(&base[at..at + prefix_share * d]);
        }
        fresh
    };

    let mut reused = 0usize;
    for s in 0..n_sessions as u64 {
        let resp = coord.submit_wait(
            AttentionRequest::prefill(
                next_id(),
                s,
                seq,
                d,
                heads,
                kv_heads,
                rng.normal_matrix(heads * seq, d),
                share(&sys_k, rng.normal_matrix(kv_heads * seq, d)),
                share(&sys_v, rng.normal_matrix(kv_heads * seq, d)),
            )
            .with_mask(mask),
        )?;
        resp.output.map_err(|e| anyhow::anyhow!("prefill of session {s} failed: {e}"))?;
        reused += resp.stats.prefix_reused_tokens;
    }
    println!("{n_sessions} sessions prefilled at L={seq} (mask {mask})");
    if prefix_share > 0 {
        println!(
            "prefix cache: {reused} prompt tokens resumed from shared pages \
             ({prefix_share}-token system prefix)"
        );
    }

    let t0 = std::time::Instant::now();
    let (mut hits, mut misses) = (0usize, 0usize);
    for step in 0..steps as u64 {
        for s in 0..n_sessions as u64 {
            let resp = coord.submit_wait(AttentionRequest::decode(
                next_id(),
                s,
                step,
                d,
                heads,
                kv_heads,
                rng.normal_matrix(heads, d),
                rng.normal_matrix(kv_heads, d),
                rng.normal_matrix(kv_heads, d),
            ))?;
            resp.output
                .map_err(|e| anyhow::anyhow!("decode step {step} of session {s} failed: {e}"))?;
            hits += resp.stats.kv_hits;
            misses += resp.stats.kv_misses;
        }
    }
    let wall = t0.elapsed();

    for s in 0..n_sessions as u64 {
        coord.submit_wait(AttentionRequest::close(next_id(), s))?;
    }

    let total_tokens = n_sessions * steps;
    println!(
        "decoded {steps} steps x {n_sessions} sessions ({total_tokens} tokens) in {wall:.2?} \
         host time ({:.0} tokens/s host)",
        total_tokens as f64 / wall.as_secs_f64()
    );
    println!(
        "kv cache: {hits} hit / {misses} miss shards ({:.1}% hit rate)",
        100.0 * hits as f64 / (hits + misses).max(1) as f64
    );
    finish(coord, metrics_json.as_deref())
}
