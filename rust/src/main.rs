//! `fsa` — the leader binary: experiment reports, device inspection, and
//! the serving loop.  Run `fsa help` for the command list.

use std::path::PathBuf;

use fsa::cli::Args;
use fsa::config::RunConfig;
use fsa::coordinator::request::AttentionRequest;
use fsa::coordinator::Coordinator;
use fsa::experiments;
use fsa::kernel::{flash_attention_program, FlashLayout, FlashParams};
use fsa::numerics::SplitMix64;

const HELP: &str = "\
fsa — SystolicAttention / FSA reproduction

USAGE: fsa <command> [--flag value]...

Experiment commands (paper artifact regeneration):
  table1                       accelerator configurations
  fig1    [--seq 8192]         component active-time breakdown
  fig11   [--seqs 2048,..]     FLOPs/s utilization comparison
  fig12   [--segments 1,2,..]  exp2 PWL error sweep
  table2  [--seqs 2048,4096] [--artifacts DIR] [--seed N]
                               end-to-end accuracy via PJRT artifacts
  table3  [--n 128]            area breakdown
  cycles  [--sizes 4,8,16,32]  cycle-sim vs closed-form validation

Device / serving commands:
  disasm  [--seq 512 --d 128]  compile + disassemble the flash kernel
  serve   [--requests 16 --devices 2 --seq 512 --artifacts DIR]
          [--heads 1 --kv-heads 1 --backend pjrt|reference|auto]
                               boot the coordinator and serve a workload
                               (multi-head/GQA requests are sharded
                               per head across the device pool)
  help                         this text
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> fsa::Result<()> {
    match args.command.as_str() {
        "table1" => println!("{}", experiments::table1_report()),
        "fig1" => {
            let seq = args.get("seq", 8192usize)?;
            println!("{}", experiments::fig1_report(seq));
        }
        "fig11" => {
            let seqs = args.get_list("seqs", &fsa::accel::paper_seq_lens())?;
            let d = args.get("d", 128usize)?;
            println!("{}", experiments::fig11_report(&seqs, d));
        }
        "fig12" => {
            let segs = args.get_list("segments", &[1, 2, 4, 8, 16, 32, 64])?;
            println!("{}", experiments::fig12_report(&segs));
        }
        "table2" => {
            let seqs = args.get_list("seqs", &[128, 512, 2048, 4096])?;
            let d = args.get("d", 128usize)?;
            let seed = args.get("seed", 0xF5Au64)?;
            let dir = PathBuf::from(args.flag("artifacts").unwrap_or("artifacts"));
            println!("{}", experiments::table2_report(&dir, &seqs, d, seed)?);
        }
        "table3" => {
            let n = args.get("n", 128usize)?;
            println!("{}", experiments::table3_report(n));
        }
        "cycles" => {
            let sizes = args.get_list("sizes", &[4, 8, 16, 32])?;
            println!("{}", experiments::cycles_report(&sizes));
        }
        "disasm" => {
            let seq = args.get("seq", 512usize)?;
            let d = args.get("d", 128usize)?;
            let p = FlashParams {
                seq_len: seq,
                d,
                spad_elems: (6 * d * d) as u32,
                accum_elems: (d * d + d) as u32,
            };
            let prog = flash_attention_program(&p, &FlashLayout::packed(&p))?;
            let (l, s, c) = prog.class_counts();
            println!(
                "FlashAttention program for seq={seq} d={d}: {} instructions \
                 ({l} loads, {s} stores, {c} compute)\n",
                prog.len()
            );
            println!("{}", prog.disasm());
        }
        "serve" => serve(args)?,
        _ => println!("{HELP}"),
    }
    Ok(())
}

fn serve(args: &Args) -> fsa::Result<()> {
    let mut cfg = RunConfig::default();
    cfg.devices = args.get("devices", cfg.devices)?;
    cfg.max_batch = args.get("max-batch", cfg.max_batch)?;
    cfg.artifacts_dir = args.flag("artifacts").unwrap_or("artifacts").to_string();
    cfg.backend = args.flag("backend").unwrap_or("pjrt").parse()?;
    cfg.num_heads = args.get("heads", cfg.num_heads)?;
    cfg.num_kv_heads = args.get("kv-heads", cfg.num_kv_heads)?;
    let n_req = args.get("requests", 16usize)?;
    let seq = args.get("seq", 512usize)?;
    let d = args.get("d", 128usize)?;
    let (heads, kv_heads) = (cfg.num_heads, cfg.num_kv_heads);
    // Head-count invariants are validated once by Coordinator::start
    // (RunConfig::validate) before any request is constructed.

    println!(
        "booting coordinator: {} devices, backend {}, artifacts at {}",
        cfg.devices, cfg.backend, cfg.artifacts_dir
    );
    let coord = Coordinator::start(cfg)?;
    let mut rng = SplitMix64::new(1);
    let mut pending = Vec::new();
    for id in 0..n_req as u64 {
        let q = rng.normal_matrix(heads * seq, d);
        let k = rng.normal_matrix(kv_heads * seq, d);
        let v = rng.normal_matrix(kv_heads * seq, d);
        pending.push(coord.submit(AttentionRequest::gqa(id, seq, d, heads, kv_heads, q, k, v))?);
    }
    let mut ok = 0;
    let mut worst_util = f64::INFINITY;
    for rx in pending {
        let resp = rx.recv().map_err(|_| anyhow::anyhow!("worker dropped request"))?;
        if resp.output.is_ok() {
            ok += 1;
            worst_util = worst_util.min(resp.utilization);
        } else if let Err(e) = &resp.output {
            eprintln!("request {} failed: {e}", resp.id);
        }
    }
    println!(
        "{}/{} requests served ({heads} heads / {kv_heads} KV heads each)",
        ok, n_req
    );
    if ok > 0 {
        println!("worst whole-operator FLOPs/s utilization: {:.1}%", 100.0 * worst_util);
    }
    println!("{}", coord.metrics.summary());
    coord.shutdown();
    Ok(())
}
