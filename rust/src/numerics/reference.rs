//! Host-side reference attention implementations (row-major f32 matrices).
//!
//! These are the oracles the cycle simulator and the serving path are
//! checked against inside Rust — the same ladder as the Python side:
//! dense SDPA (exact), tiled FlashAttention with exact exp2, and tiled
//! FlashAttention with the PWL exp2 (the strict twin of both the Pallas
//! kernel and the FSA device).

use crate::mask::{MaskKind, TileCoverage};
use crate::numerics::f16::quantize_ftz_f32 as quantize_f32;
use crate::numerics::pwl::PwlExp2;
use crate::numerics::LOG2E;

/// Precision regime of matmul operands (state is always f32).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Operands quantized to fp16 before each multiply (FSA / Table 1).
    F16F32,
    /// Pure f32 (used by tests against the f32 Pallas path).
    F32,
}

/// Row-major matrix view helpers.
#[derive(Clone, Debug)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Quantize every element through fp16 (activation load on FSA).
    pub fn quantized(&self) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| quantize_f32(x)).collect(),
        }
    }

    /// Borrow this matrix as a [`MatView`].
    pub fn view(&self) -> MatView<'_> {
        MatView { rows: self.rows, cols: self.cols, data: &self.data }
    }
}

/// A borrowed row-major matrix — the zero-copy twin of [`Mat`]
/// (DESIGN.md §12).  [`ShardPlan`](crate::runtime::ShardPlan) already
/// carries borrowed slices, so the reference backend wraps them here
/// and the kernels quantize (or materialize) straight from the
/// caller's storage instead of copying into an owned `Mat` first.
#[derive(Clone, Copy, Debug)]
pub struct MatView<'a> {
    pub rows: usize,
    pub cols: usize,
    pub data: &'a [f32],
}

impl<'a> MatView<'a> {
    pub fn new(rows: usize, cols: usize, data: &'a [f32]) -> MatView<'a> {
        assert_eq!(data.len(), rows * cols);
        MatView { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Quantize every element through fp16, materializing an owned
    /// [`Mat`] — element-for-element [`Mat::quantized`].
    pub fn quantized(&self) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| quantize_f32(x)).collect(),
        }
    }

    /// Materialize an owned copy (the f32 path's one necessary copy).
    pub fn to_mat(&self) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.to_vec() }
    }
}

#[inline]
fn q(x: f32, p: Precision) -> f32 {
    match p {
        Precision::F16F32 => quantize_f32(x),
        Precision::F32 => x,
    }
}

/// Dense fp32 SDPA: softmax(Q K^T / sqrt(d)) V.  Exact reference.
pub fn sdpa(qm: &Mat, km: &Mat, vm: &Mat) -> Mat {
    sdpa_masked(qm, km, vm, MaskKind::None)
}

/// Masked dense SDPA: masked `(i, j)` pairs are *excluded* from the
/// softmax (weight exactly zero — not a large-negative approximation),
/// so this is the exact semantic reference for every [`MaskKind`].
/// Rows with no valid keys produce a zero output row by definition.
pub fn sdpa_masked(qm: &Mat, km: &Mat, vm: &Mat, mask: MaskKind) -> Mat {
    let (l, d) = (qm.rows, qm.cols);
    let lk = km.rows;
    assert_eq!(km.cols, d);
    assert_eq!(vm.rows, lk);
    let scale = 1.0 / (d as f64).sqrt();
    let mut out = Mat::zeros(l, vm.cols);
    let mut row = vec![0.0f64; lk];
    for i in 0..l {
        // Valid keys are a prefix (see MaskKind::valid_keys).
        let vk = mask.valid_keys(i, lk);
        if vk == 0 {
            continue; // fully-masked row: zero output
        }
        let mut maxv = f64::NEG_INFINITY;
        for j in 0..vk {
            let mut s = 0.0f64;
            for k in 0..d {
                s += qm.at(i, k) as f64 * km.at(j, k) as f64;
            }
            let s = s * scale;
            row[j] = s;
            maxv = maxv.max(s);
        }
        let mut denom = 0.0f64;
        for j in 0..vk {
            row[j] = (row[j] - maxv).exp();
            denom += row[j];
        }
        for h in 0..vm.cols {
            let mut acc = 0.0f64;
            for j in 0..vk {
                acc += row[j] * vm.at(j, h) as f64;
            }
            out.set(i, h, (acc / denom) as f32);
        }
    }
    out
}

/// exp2 evaluator used by the flash reference.
pub enum Exp2 {
    Exact,
    /// PWL computed in f32 (the f32 Pallas path).
    Pwl(PwlExp2),
    /// PWL with the interpolation MAC in fp16 — the PE datapath.
    PwlF16(PwlExp2),
}

impl Exp2 {
    #[inline]
    fn eval(&self, x: f32) -> f32 {
        match self {
            Exp2::Exact => x.exp2(),
            Exp2::Pwl(p) => p.eval_f32(x),
            Exp2::PwlF16(p) => p.eval_f16_mac(x),
        }
    }
}

/// Tiled FlashAttention-2 forward, Algorithm 1 of the paper, with either
/// exact or PWL exp2 and fp16-or-f32 matmul operands.  Bit-order faithful:
/// the first matmul accumulates over k descending (the upward systolic
/// path sums from the bottom row up), rowsum and PV accumulate over n
/// ascending (downward path).  Exact tiling required (the original API);
/// [`flash_forward_masked`] additionally supports masks and ragged final
/// tiles.
pub fn flash_forward(
    qm: &Mat,
    km: &Mat,
    vm: &Mat,
    br: usize,
    bc: usize,
    exp2: &Exp2,
    prec: Precision,
) -> Mat {
    assert!(
        qm.rows % br == 0 && km.rows % bc == 0,
        "tile sizes must divide seq lens"
    );
    flash_forward_masked(qm, km, vm, br, bc, exp2, prec, MaskKind::None)
}

/// Partial online-softmax state of a flash forward pass over a key/value
/// *chunk* — the unit sequence-parallel attention ships between devices
/// (DESIGN.md §7).
///
/// Per query row `r` the triple is exactly flash's running state after
/// the chunk's tiles: `m[r]` the running (scaled-domain) row max, `l[r]`
/// the running rowsum of stored P, and `acc[r*d..]` the *unnormalized*
/// output accumulator (`diag(l) O` in paper notation).  A row the chunk
/// never touched (fully masked there) keeps `l == 0` and the finite
/// `-inf` stand-in in `m` — the state a fresh kernel starts from, which
/// is what makes merging such a row the identity.
#[derive(Clone, Debug, PartialEq)]
pub struct FlashPartial {
    pub rows: usize,
    pub d: usize,
    /// Row-major `(rows, d)` unnormalized accumulator.
    pub acc: Vec<f32>,
    /// Per-row running max (finite `-inf` stand-in when untouched).
    pub m: Vec<f32>,
    /// Per-row running rowsum (`0` = row untouched / fully masked).
    pub l: Vec<f32>,
}

/// Finite `-inf` stand-in shared by every flash kernel here (a true
/// `-inf` would feed NaN through the Split unit's `x - ceil(x)`).
pub const NEG_INF: f32 = -1e30;

impl FlashPartial {
    /// The empty state every flash pass starts from (`l = 0` rows).
    pub fn empty(rows: usize, d: usize) -> FlashPartial {
        FlashPartial {
            rows,
            d,
            acc: vec![0.0; rows * d],
            m: vec![NEG_INF; rows],
            l: vec![0.0; rows],
        }
    }

    /// Merge `other` (the next chunk, in chunk order) into this running
    /// state with flash's own outer-loop update rule: take the new row
    /// max, rescale both sides by `exp2(scale · (old_max − new_max))`,
    /// and add.  Exactness structure (pinned by unit tests):
    ///
    /// * a fully-masked (`l == 0`) incoming row is skipped — merging it
    ///   is the identity, the same legality argument as tile skipping;
    /// * the first live chunk of a row is *adopted* bitwise (flash's own
    ///   initialization — its first tile's state is not "merged into"
    ///   anything either);
    /// * the fold is defined over chunk order `0..n` — merging in tree
    ///   order is a different FP reassociation and is NOT the contract.
    ///
    /// The merged result is therefore a pure function of the chunk
    /// boundaries — bitwise-invariant to which device computed which
    /// chunk — and degenerates bitwise to the plain kernel for a single
    /// chunk.  (Across *different* chunkings it is mathematically equal
    /// but, like any FP reassociation — or a tile-size change — not
    /// bitwise; DESIGN.md §7.)
    pub fn merge_from(&mut self, other: &FlashPartial, exp2: &Exp2) {
        assert_eq!(
            (self.rows, self.d),
            (other.rows, other.d),
            "partial shapes must agree"
        );
        let scale = (LOG2E / (self.d as f64).sqrt()) as f32;
        for r in 0..self.rows {
            if other.l[r] == 0.0 {
                continue; // fully-masked chunk row: merging is the identity
            }
            let (lo, hi) = (r * self.d, (r + 1) * self.d);
            if self.l[r] == 0.0 {
                // First live chunk: adopt bitwise (flash's initial state).
                self.m[r] = other.m[r];
                self.l[r] = other.l[r];
                self.acc[lo..hi].copy_from_slice(&other.acc[lo..hi]);
                continue;
            }
            let new_m = self.m[r].max(other.m[r]);
            let b_run = exp2.eval(scale * (self.m[r] - new_m));
            let b_inc = exp2.eval(scale * (other.m[r] - new_m));
            self.l[r] = self.l[r] * b_run + other.l[r] * b_inc;
            for h in lo..hi {
                self.acc[h] = self.acc[h] * b_run + other.acc[h] * b_inc;
            }
            self.m[r] = new_m;
        }
    }

    /// Normalize into the final output: `out[r] = acc[r] / l[r]`, with
    /// fully-masked rows (`l == 0`) a defined zero row — the exact final
    /// block of the tiled kernel, so `partial.finalize()` over a whole
    /// sequence IS the kernel, operation for operation.
    pub fn finalize(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.d);
        for r in 0..self.rows {
            if self.l[r] == 0.0 {
                continue; // fully-masked row: defined zero output
            }
            let inv = 1.0 / self.l[r];
            for h in 0..self.d {
                out.set(r, h, self.acc[r * self.d + h] * inv);
            }
        }
        out
    }
}

/// Fold partials in chunk order `0..n` and normalize — the gather-side
/// merge of sequence-parallel serving (DESIGN.md §7).  One partial
/// degenerates bitwise to the plain kernel output.
pub fn merge_partials(parts: &[FlashPartial], exp2: &Exp2) -> Mat {
    assert!(!parts.is_empty(), "need at least one partial");
    let mut state = FlashPartial::empty(parts[0].rows, parts[0].d);
    for p in parts {
        state.merge_from(p, exp2);
    }
    state.finalize()
}

/// Masked tiled FlashAttention with the tile-skipping schedule
/// (DESIGN.md §6).  Generalizes [`flash_forward`]:
///
/// * **Mask before the update.**  Within each tile the mask is applied
///   *before* the online-softmax update: masked lanes are excluded from
///   the tile row-max and their stored P is zeroed (the device's
///   element-wise mask wave), so the paper's FP operation order over the
///   valid lanes is untouched — masking is exact, not a large-negative
///   approximation.
/// * **Tile skipping.**  A fully-masked tile is skipped outright; a row
///   with no valid key in a tile leaves its `(m, l, O)` state untouched.
///   Both are exact because a fully-masked tile/row contributes nothing
///   to any online-softmax state (legality argument in DESIGN.md §6).
///   For causal this drops the whole upper triangle — ≈2× fewer tiles.
/// * **Ragged tiles.**  The final row/column tile may be short (same
///   rule as [`flash_decode_row`]), so any sequence length tiles at the
///   array size.  With exact tiling and `MaskKind::None` the arithmetic
///   is operation-for-operation that of the original kernel.
/// * **Fully-masked rows** (no valid key anywhere) produce a zero output
///   row by definition (their `l` stays 0, which would otherwise 0/0).
#[allow(clippy::too_many_arguments)]
pub fn flash_forward_masked(
    qm: &Mat,
    km: &Mat,
    vm: &Mat,
    br: usize,
    bc: usize,
    exp2: &Exp2,
    prec: Precision,
    mask: MaskKind,
) -> Mat {
    flash_forward_partial(qm, km, vm, br, bc, exp2, prec, mask, 0, km.rows).finalize()
}

/// One sequence-parallel *chunk* of [`flash_forward_masked`]
/// (DESIGN.md §7): run the tiled kernel over the key/value chunk
/// `km`/`vm`, which covers *global* key indices `[key_offset,
/// key_offset + km.rows)` of a `total_keys`-key sequence, and return the
/// per-row partial `(acc, m, l)` state instead of normalizing.  The mask
/// is evaluated at global key coordinates, so per-chunk masking (causal
/// intersection, padding boundaries, whole-chunk skips) is exactly the
/// tile-skipping schedule restricted to the chunk.  With `key_offset = 0`
/// and the whole key sequence this is operation-for-operation the body
/// of [`flash_forward_masked`] (which delegates here), so
/// `finalize()` of a single whole-range chunk IS the plain kernel.
#[allow(clippy::too_many_arguments)]
pub fn flash_forward_partial(
    qm: &Mat,
    km: &Mat,
    vm: &Mat,
    br: usize,
    bc: usize,
    exp2: &Exp2,
    prec: Precision,
    mask: MaskKind,
    key_offset: usize,
    total_keys: usize,
) -> FlashPartial {
    flash_forward_partial_at(qm, km, vm, br, bc, exp2, prec, mask, 0, key_offset, total_keys)
}

/// [`flash_forward_partial`] resumed at a *global query row offset*
/// (DESIGN.md §11): `qm` holds only the suffix query rows, whose global
/// indices are `[query_offset, query_offset + qm.rows)`, and the mask is
/// evaluated at those global row coordinates.  Because every per-row
/// online-softmax update depends only on that row's Q, the key tiling,
/// and the row's own valid-key prefix — never on which rows share its
/// row block (the `br = 1` decode pin is the degenerate case of this
/// independence) — the returned partial rows are **bitwise identical**
/// to the corresponding rows of the `query_offset = 0` whole-query run
/// (pinned by a unit test).  This is the prefix-cache warm-prefill
/// kernel: rows `[0, query_offset)` were served from cached pages and
/// are simply not recomputed.  `query_offset = 0` is
/// operation-for-operation [`flash_forward_partial`], which delegates
/// here.
#[allow(clippy::too_many_arguments)]
pub fn flash_forward_partial_at(
    qm: &Mat,
    km: &Mat,
    vm: &Mat,
    br: usize,
    bc: usize,
    exp2: &Exp2,
    prec: Precision,
    mask: MaskKind,
    query_offset: usize,
    key_offset: usize,
    total_keys: usize,
) -> FlashPartial {
    flash_forward_partial_at_view(
        qm.view(),
        km.view(),
        vm.view(),
        br,
        bc,
        exp2,
        prec,
        mask,
        query_offset,
        key_offset,
        total_keys,
    )
}

/// [`flash_forward_partial_at`] on borrowed [`MatView`] operands — the
/// zero-copy workhorse every owned-`Mat` entry point delegates to.
/// Pre-quantization materializes owned operands (fp16 quantization has
/// to write *somewhere*), but it reads straight from the caller's
/// storage, so a view caller pays one materialization instead of a
/// `to_vec` copy *plus* the materialization.
#[allow(clippy::too_many_arguments)]
pub fn flash_forward_partial_at_view(
    qm: MatView<'_>,
    km: MatView<'_>,
    vm: MatView<'_>,
    br: usize,
    bc: usize,
    exp2: &Exp2,
    prec: Precision,
    mask: MaskKind,
    query_offset: usize,
    key_offset: usize,
    total_keys: usize,
) -> FlashPartial {
    let (l, d) = (qm.rows, qm.cols);
    let lk = km.rows;
    assert_eq!(km.cols, d);
    assert_eq!(vm.rows, lk);
    assert!(br >= 1 && bc >= 1, "tile sizes must be >= 1");
    assert!(
        key_offset + lk <= total_keys,
        "chunk [{key_offset}, {}) exceeds the {total_keys}-key sequence",
        key_offset + lk
    );
    let scale = (LOG2E / (d as f64).sqrt()) as f32;

    let mut part = FlashPartial::empty(l, d);
    let mut s = vec![0.0f32; br * bc];
    let mut p16 = vec![0.0f32; br * bc];

    // Quantization is idempotent: pre-quantize the operands once instead
    // of per-MAC inside the O(L^2 d) loops (EXPERIMENTS.md §Perf).
    let (qq, kq, vq) = match prec {
        Precision::F16F32 => (qm.quantized(), km.quantized(), vm.quantized()),
        Precision::F32 => (qm.to_mat(), km.to_mat(), vm.to_mat()),
    };
    let (qm, km, vm) = (&qq, &kq, &vq);

    let mut q0 = 0;
    while q0 < l {
        let bre = br.min(l - q0);
        let m = &mut part.m[q0..q0 + bre];
        let lsum = &mut part.l[q0..q0 + bre];
        let acc = &mut part.acc[q0 * d..(q0 + bre) * d];
        let mut k0 = 0;
        while k0 < lk {
            let bce = bc.min(lk - k0);
            // Tile-skipping schedule: a fully-masked tile touches no row
            // state, so skipping it is exact.  Coverage and valid-key
            // prefixes are evaluated at *global* coordinates on both
            // axes (query_offset for resumed prefills, key_offset for
            // sequence chunks).
            if mask.coverage(query_offset + q0, bre, key_offset + k0, bce) == TileCoverage::Empty {
                k0 += bce;
                continue;
            }
            for r in 0..bre {
                // Valid keys form a per-row prefix of the tile's columns
                // (both mask kinds are column-prefix masks).
                let vc = mask
                    .valid_keys(query_offset + q0 + r, total_keys)
                    .saturating_sub(key_offset + k0)
                    .min(bce);
                if vc == 0 {
                    continue; // row fully masked in this tile: state untouched
                }
                // S = Q K^T, fp32 psums, k-descending accumulation order
                // (upward path starts at the bottom row of the array).
                let qrow = &qm.data[(q0 + r) * d..(q0 + r + 1) * d];
                for c in 0..vc {
                    let krow = &km.data[(k0 + c) * d..(k0 + c + 1) * d];
                    let mut ps = 0.0f32;
                    for k in (0..d).rev() {
                        ps += qrow[k] * krow[k];
                    }
                    s[r * bc + c] = ps;
                }
                // The device parks S in fp16 result registers; rowmax and
                // the whole elementwise chain run on those values, and the
                // rowsum sums the *stored* (quantized, flushed) P.  Masked
                // lanes are excluded from the rowmax and their P is zeroed
                // (the mask wave) before the rowsum.
                let mut local_m = f32::NEG_INFINITY;
                for c in 0..vc {
                    s[r * bc + c] = q(s[r * bc + c], prec);
                    local_m = local_m.max(s[r * bc + c]);
                }
                let new_m = m[r].max(local_m);
                let b = exp2.eval(scale * (m[r] - new_m));
                let mut local_l = 0.0f32;
                for c in 0..vc {
                    let nv = q(s[r * bc + c] - new_m, prec);
                    let pv = exp2.eval(q(scale * nv, prec));
                    p16[r * bc + c] = q(pv, prec);
                    local_l += p16[r * bc + c];
                }
                for c in vc..bce {
                    p16[r * bc + c] = 0.0;
                    local_l += p16[r * bc + c];
                }
                lsum[r] = lsum[r] * b + local_l;
                m[r] = new_m;
                // Rescale the accumulator (diag(b) old_O) now; PV adds in
                // the n-ascending loop below.
                for h in 0..d {
                    acc[r * d + h] *= b;
                }
            }
            // O += P V, n-ascending (downward path, top row first); the
            // masked lanes ride along with P = 0, exactly as on the array.
            for r in 0..bre {
                if mask.valid_keys(query_offset + q0 + r, total_keys) <= key_offset + k0 {
                    continue; // row skipped above: stale P, state untouched
                }
                for h in 0..d {
                    let mut ps = 0.0f32;
                    for n in 0..bce {
                        ps += p16[r * bc + n] * vm.at(k0 + n, h);
                    }
                    acc[r * d + h] += ps;
                }
            }
            k0 += bce;
        }
        q0 += bre;
    }
    part
}

/// Single-query-row FlashAttention over a `(len, d)` K/V prefix — the
/// decode-phase kernel (DESIGN.md §5).
///
/// This is the `br = 1` degeneration of [`flash_forward`], streaming
/// the prefix in column tiles of `bc` tokens (a ragged final tile is
/// allowed, so any prefix length works — decode prefixes grow by one
/// token per step).  Every quantization point matches the prefill
/// path: fp32 psums over quantized operands, fp16 parking of S, the
/// PWL exp2 on the quantized argument, fp16 storage of P, and the
/// same accumulation orders (k-descending first matmul, n-ascending
/// rowsum/PV).  When `bc` divides `len` the output is **bitwise
/// identical** to `flash_forward` with `br = 1` on the same inputs
/// (pinned by a unit test) — which is exactly what makes cached
/// decode, miss-path recompute, and stateless full-prefix
/// recomputation agree bit-for-bit in the serving e2e tests.
///
/// Stateless recompute and the cached path both call this function —
/// the cache changes where the K/V bytes come from (device pages vs
/// host tier) and what the step costs, never the numerics.
pub fn flash_decode_row(
    qr: &[f32],
    km: &[f32],
    vm: &[f32],
    d: usize,
    bc: usize,
    exp2: &Exp2,
    prec: Precision,
) -> Vec<f32> {
    let part = flash_decode_row_partial(qr, km, vm, d, bc, exp2, prec);
    // Normalization kept verbatim from the original kernel (not
    // `finalize()`): decode has no masked rows, so `l` is never the
    // defined-zero case and the historical `1/l` behavior is preserved
    // bit for bit.
    let inv = 1.0 / part.l[0];
    part.acc.iter().map(|&a| a * inv).collect()
}

/// One sequence-parallel K/V *range* of [`flash_decode_row`] — the
/// flash-decode-style split-KV unit (DESIGN.md §7): the single query row
/// attends a contiguous slice of the prefix and emits its partial
/// `(acc, m, l)` row instead of normalizing.  Decode takes no mask (the
/// step row attends the whole prefix), so unlike
/// [`flash_forward_partial`] the range carries no global key offset —
/// scores are offset-invariant.  The whole-prefix range normalized is
/// bitwise [`flash_decode_row`] (which delegates here).
pub fn flash_decode_row_partial(
    qr: &[f32],
    km: &[f32],
    vm: &[f32],
    d: usize,
    bc: usize,
    exp2: &Exp2,
    prec: Precision,
) -> FlashPartial {
    assert!(d >= 1 && bc >= 1);
    assert_eq!(qr.len(), d, "q must be one (1, d) row");
    assert_eq!(km.len() % d, 0, "K must be (len, d) row-major");
    assert_eq!(km.len(), vm.len(), "K and V must agree");
    let lk = km.len() / d;
    assert!(lk >= 1, "need at least one prefix token");
    let scale = (LOG2E / (d as f64).sqrt()) as f32;

    let qq: Vec<f32> = qr.iter().map(|&x| q(x, prec)).collect();
    let kq: Vec<f32> = km.iter().map(|&x| q(x, prec)).collect();
    let vq: Vec<f32> = vm.iter().map(|&x| q(x, prec)).collect();

    let mut m = NEG_INF;
    let mut lsum = 0.0f32;
    let mut acc = vec![0.0f32; d];
    let mut s = vec![0.0f32; bc];
    let mut p16 = vec![0.0f32; bc];

    let mut k0 = 0;
    while k0 < lk {
        let bce = bc.min(lk - k0);
        for c in 0..bce {
            let krow = &kq[(k0 + c) * d..(k0 + c + 1) * d];
            let mut ps = 0.0f32;
            for k in (0..d).rev() {
                ps += qq[k] * krow[k];
            }
            s[c] = ps;
        }
        let mut local_m = f32::NEG_INFINITY;
        for c in 0..bce {
            s[c] = q(s[c], prec);
            local_m = local_m.max(s[c]);
        }
        let new_m = m.max(local_m);
        let b = exp2.eval(scale * (m - new_m));
        let mut local_l = 0.0f32;
        for c in 0..bce {
            let nv = q(s[c] - new_m, prec);
            let pv = exp2.eval(q(scale * nv, prec));
            p16[c] = q(pv, prec);
            local_l += p16[c];
        }
        lsum = lsum * b + local_l;
        m = new_m;
        for a in acc.iter_mut() {
            *a *= b;
        }
        for (h, a) in acc.iter_mut().enumerate() {
            let mut ps = 0.0f32;
            for n in 0..bce {
                ps += p16[n] * vq[(k0 + n) * d + h];
            }
            *a += ps;
        }
        k0 += bce;
    }
    FlashPartial { rows: 1, d, acc, m: vec![m], l: vec![lsum] }
}

/// Convenience: the decode row with the paper's device numerics (PWL
/// exp2, fp16 operand quantization) — the strict twin the device
/// workers' reference backend runs for decode shards.
pub fn decode_pwl(qr: &[f32], km: &[f32], vm: &[f32], d: usize, bc: usize, segments: usize) -> Vec<f32> {
    flash_decode_row(
        qr, km, vm, d, bc,
        &Exp2::PwlF16(PwlExp2::new(segments)),
        Precision::F16F32,
    )
}

/// Convenience: PWL flash with the paper's defaults (used as the
/// device-numerics oracle everywhere in the Rust tests).
pub fn flash_pwl(qm: &Mat, km: &Mat, vm: &Mat, br: usize, bc: usize, segments: usize) -> Mat {
    flash_forward(
        qm, km, vm, br, bc,
        &Exp2::PwlF16(PwlExp2::new(segments)),
        Precision::F16F32,
    )
}

/// Convenience: masked PWL flash with the paper's device numerics —
/// the strict twin the device workers' reference backend runs for
/// masked shards (ragged tiling allowed, see [`flash_forward_masked`]).
pub fn flash_pwl_masked(
    qm: &Mat,
    km: &Mat,
    vm: &Mat,
    br: usize,
    bc: usize,
    segments: usize,
    mask: MaskKind,
) -> Mat {
    flash_forward_masked(
        qm, km, vm, br, bc,
        &Exp2::PwlF16(PwlExp2::new(segments)),
        Precision::F16F32,
        mask,
    )
}

/// Convenience: one sequence chunk with the paper's device numerics —
/// the strict twin the device workers' reference backend runs for
/// sequence-sharded shards (DESIGN.md §7).
#[allow(clippy::too_many_arguments)]
pub fn flash_pwl_partial(
    qm: &Mat,
    km: &Mat,
    vm: &Mat,
    br: usize,
    bc: usize,
    segments: usize,
    mask: MaskKind,
    key_offset: usize,
    total_keys: usize,
) -> FlashPartial {
    flash_forward_partial(
        qm, km, vm, br, bc,
        &Exp2::PwlF16(PwlExp2::new(segments)),
        Precision::F16F32,
        mask,
        key_offset,
        total_keys,
    )
}

/// Convenience: one resumed-prefill chunk with the paper's device
/// numerics — the strict twin the device workers' reference backend
/// runs for prefix-cache warm prefills (`qm` = suffix query rows at
/// global offset `query_offset`, see [`flash_forward_partial_at`]).
#[allow(clippy::too_many_arguments)]
pub fn flash_pwl_resumed(
    qm: &Mat,
    km: &Mat,
    vm: &Mat,
    br: usize,
    bc: usize,
    segments: usize,
    mask: MaskKind,
    query_offset: usize,
    key_offset: usize,
    total_keys: usize,
) -> FlashPartial {
    flash_forward_partial_at(
        qm, km, vm, br, bc,
        &Exp2::PwlF16(PwlExp2::new(segments)),
        Precision::F16F32,
        mask,
        query_offset,
        key_offset,
        total_keys,
    )
}

/// [`flash_pwl_masked`] on borrowed operands — the zero-copy entry
/// point the reference backend's `ShardPlan::Head` arm executes
/// (DESIGN.md §12).  Delegates to the same view workhorse as the owned
/// wrapper, so the output is bitwise [`flash_pwl_masked`]'s.
pub fn flash_pwl_masked_view(
    qm: MatView<'_>,
    km: MatView<'_>,
    vm: MatView<'_>,
    br: usize,
    bc: usize,
    segments: usize,
    mask: MaskKind,
) -> Mat {
    flash_forward_partial_at_view(
        qm, km, vm, br, bc,
        &Exp2::PwlF16(PwlExp2::new(segments)),
        Precision::F16F32,
        mask,
        0,
        0,
        km.rows,
    )
    .finalize()
}

/// [`flash_pwl_partial`] on borrowed operands — the zero-copy entry
/// point the reference backend's `ShardPlan::HeadChunk` arm executes.
#[allow(clippy::too_many_arguments)]
pub fn flash_pwl_partial_view(
    qm: MatView<'_>,
    km: MatView<'_>,
    vm: MatView<'_>,
    br: usize,
    bc: usize,
    segments: usize,
    mask: MaskKind,
    key_offset: usize,
    total_keys: usize,
) -> FlashPartial {
    flash_forward_partial_at_view(
        qm, km, vm, br, bc,
        &Exp2::PwlF16(PwlExp2::new(segments)),
        Precision::F16F32,
        mask,
        0,
        key_offset,
        total_keys,
    )
}

/// [`flash_pwl_resumed`] on borrowed operands — the zero-copy entry
/// point the reference backend's `ShardPlan::ResumedPrefill` arm
/// executes.
#[allow(clippy::too_many_arguments)]
pub fn flash_pwl_resumed_view(
    qm: MatView<'_>,
    km: MatView<'_>,
    vm: MatView<'_>,
    br: usize,
    bc: usize,
    segments: usize,
    mask: MaskKind,
    query_offset: usize,
    key_offset: usize,
    total_keys: usize,
) -> FlashPartial {
    flash_forward_partial_at_view(
        qm, km, vm, br, bc,
        &Exp2::PwlF16(PwlExp2::new(segments)),
        Precision::F16F32,
        mask,
        query_offset,
        key_offset,
        total_keys,
    )
}

/// Convenience: one split-KV decode range with the paper's device
/// numerics — the strict twin the reference backend runs for
/// sequence-sharded decode shards (DESIGN.md §7).
pub fn decode_pwl_partial(
    qr: &[f32],
    km: &[f32],
    vm: &[f32],
    d: usize,
    bc: usize,
    segments: usize,
) -> FlashPartial {
    flash_decode_row_partial(
        qr, km, vm, d, bc,
        &Exp2::PwlF16(PwlExp2::new(segments)),
        Precision::F16F32,
    )
}

/// Error statistics between two equally-shaped matrices (Table 2 metrics).
#[derive(Clone, Copy, Debug, Default)]
pub struct MatError {
    pub mae: f64,
    pub rmse: f64,
    pub mre: f64,
    pub max_abs: f64,
}

pub fn mat_error(got: &Mat, want: &Mat) -> MatError {
    assert_eq!(got.rows, want.rows);
    assert_eq!(got.cols, want.cols);
    let n = got.data.len();
    let (mut abs_sum, mut sq_sum, mut rel_sum, mut max_abs) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for i in 0..n {
        let g = got.data[i] as f64;
        let w = want.data[i] as f64;
        let abs = (g - w).abs();
        abs_sum += abs;
        sq_sum += abs * abs;
        // Paper MRE convention: |err| / (|ref| + eps) with eps guarding
        // zero outputs (attention outputs are rarely exactly zero).
        rel_sum += abs / (w.abs() + 1e-9);
        max_abs = max_abs.max(abs);
    }
    MatError {
        mae: abs_sum / n as f64,
        rmse: (sq_sum / n as f64).sqrt(),
        mre: rel_sum / n as f64,
        max_abs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::rng::SplitMix64;

    fn rand_mat(rng: &mut SplitMix64, rows: usize, cols: usize) -> Mat {
        Mat::new(rows, cols, rng.normal_matrix(rows, cols))
    }

    #[test]
    fn flash_exact_matches_dense_sdpa() {
        let mut rng = SplitMix64::new(5);
        let (l, d) = (32, 16);
        let qm = rand_mat(&mut rng, l, d);
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let dense = sdpa(&qm, &km, &vm);
        let flash = flash_forward(&qm, &km, &vm, 8, 8, &Exp2::Exact, Precision::F32);
        let err = mat_error(&flash, &dense);
        assert!(err.max_abs < 1e-5, "{err:?}");
    }

    #[test]
    fn flash_pwl_close_to_dense() {
        let mut rng = SplitMix64::new(6);
        let (l, d) = (32, 16);
        let qm = rand_mat(&mut rng, l, d);
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let dense = sdpa(&qm, &km, &vm);
        let flash = flash_pwl(&qm, &km, &vm, 8, 8, 8);
        let err = mat_error(&flash, &dense);
        // PWL + fp16 operand error budget (paper Table 2 scale).
        assert!(err.mae < 2e-2, "{err:?}");
        assert!(err.max_abs < 2e-1, "{err:?}");
    }

    #[test]
    fn tile_shape_independence_with_exact_exp2() {
        let mut rng = SplitMix64::new(8);
        let (l, d) = (64, 16);
        let qm = rand_mat(&mut rng, l, d);
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let a = flash_forward(&qm, &km, &vm, 8, 16, &Exp2::Exact, Precision::F32);
        let b = flash_forward(&qm, &km, &vm, 32, 32, &Exp2::Exact, Precision::F32);
        assert!(mat_error(&a, &b).max_abs < 1e-5);
    }

    #[test]
    fn huge_logits_stay_finite() {
        let mut rng = SplitMix64::new(9);
        let (l, d) = (16, 8);
        let mut qm = rand_mat(&mut rng, l, d);
        for v in qm.data.iter_mut() {
            *v *= 50.0;
        }
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let out = flash_pwl(&qm, &km, &vm, 8, 8, 8);
        assert!(out.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decode_row_is_bitwise_flash_forward_br1() {
        // When bc divides the prefix length, the decode kernel must be
        // bit-for-bit the br=1 tiled flash — the invariant the serving
        // e2e leans on (cached vs recompute vs stateless all agree).
        // flash_decode_row intentionally duplicates flash_forward's
        // inner loop (the original asserts exact tiling); this sweep is
        // the lockstep guard — any change to either kernel's
        // accumulation order or quantization points must keep it green.
        let mut rng = SplitMix64::new(11);
        for (case, &(lk, d, bc)) in
            [(32usize, 16usize, 8usize), (24, 8, 24), (64, 32, 16), (16, 16, 4), (128, 64, 32)]
                .iter()
                .enumerate()
        {
            let qr = rng.normal_matrix(1, d);
            let km = rng.normal_matrix(lk, d);
            let vm = rng.normal_matrix(lk, d);
            for (exp2, prec) in [
                (Exp2::Exact, Precision::F32),
                (Exp2::Pwl(PwlExp2::new(8)), Precision::F32),
                (Exp2::PwlF16(PwlExp2::new(8)), Precision::F16F32),
                (Exp2::PwlF16(PwlExp2::new(4)), Precision::F16F32),
            ] {
                let row = flash_decode_row(&qr, &km, &vm, d, bc, &exp2, prec);
                let full = flash_forward(
                    &Mat::new(1, d, qr.clone()),
                    &Mat::new(lk, d, km.clone()),
                    &Mat::new(lk, d, vm.clone()),
                    1,
                    bc,
                    &exp2,
                    prec,
                );
                assert_eq!(
                    row, full.data,
                    "case {case} (lk={lk} d={d} bc={bc}): decode row diverged from flash br=1"
                );
            }
        }
    }

    #[test]
    fn decode_row_matches_dense_sdpa_row() {
        // Ragged prefix (not a multiple of bc): still a valid decode.
        let mut rng = SplitMix64::new(12);
        let (lk, d, bc) = (37usize, 16usize, 8usize);
        let qr = rng.normal_matrix(1, d);
        let km = rng.normal_matrix(lk, d);
        let vm = rng.normal_matrix(lk, d);
        let row = flash_decode_row(&qr, &km, &vm, d, bc, &Exp2::Exact, Precision::F32);
        let dense = sdpa(
            &Mat::new(1, d, qr.clone()),
            &Mat::new(lk, d, km.clone()),
            &Mat::new(lk, d, vm.clone()),
        );
        let err = mat_error(&Mat::new(1, d, row.clone()), &dense);
        assert!(err.max_abs < 1e-5, "{err:?}");
        // And the PWL+fp16 twin stays inside the Table-2 error band.
        let pwl = decode_pwl(&qr, &km, &vm, d, bc, 8);
        let err = mat_error(&Mat::new(1, d, pwl), &dense);
        assert!(err.mae < 2e-2, "{err:?}");
        assert!(row.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn masked_flash_matches_masked_dense_across_shapes_and_modes() {
        // Satellite coverage: masked flash vs masked dense parity across
        // shapes x numerics modes.  Exact exp2/f32 pins tight; the PWL +
        // fp16 modes stay inside the Table-2 error band.
        let mut rng = SplitMix64::new(31);
        for &(l, d, br, bc) in &[(32usize, 16usize, 8usize, 8usize), (48, 8, 16, 8), (40, 16, 16, 16), (64, 32, 32, 16)]
        {
            let qm = rand_mat(&mut rng, l, d);
            let km = rand_mat(&mut rng, l, d);
            let vm = rand_mat(&mut rng, l, d);
            for mask in [
                MaskKind::Causal,
                MaskKind::PaddingKeys { valid: l - 7 },
                MaskKind::PaddingKeys { valid: 3 },
                MaskKind::None,
            ] {
                let dense = sdpa_masked(&qm, &km, &vm, mask);
                for (exp2, prec, mae, max_abs) in [
                    (Exp2::Exact, Precision::F32, 1e-5, 1e-5),
                    (Exp2::Pwl(PwlExp2::new(8)), Precision::F32, 2e-2, 2e-1),
                    (Exp2::PwlF16(PwlExp2::new(8)), Precision::F16F32, 2e-2, 2e-1),
                    (Exp2::PwlF16(PwlExp2::new(4)), Precision::F16F32, 5e-2, 5e-1),
                ] {
                    let flash = flash_forward_masked(&qm, &km, &vm, br, bc, &exp2, prec, mask);
                    let err = mat_error(&flash, &dense);
                    assert!(
                        err.mae < mae && err.max_abs < max_abs,
                        "L={l} d={d} br={br} bc={bc} {mask:?}: {err:?}"
                    );
                    assert!(flash.data.iter().all(|x| x.is_finite()));
                }
            }
        }
    }

    #[test]
    fn masked_flash_with_none_is_bitwise_the_original_kernel() {
        // The masked kernel with MaskKind::None and exact tiling must be
        // operation-for-operation the original flash_forward (which now
        // delegates) — pinned against the independently-implemented
        // decode kernel via the br=1 lockstep test below, and here
        // against ragged whole-tile degeneration: one ragged tile of
        // size lk equals one exact tile of size lk.
        let mut rng = SplitMix64::new(33);
        let (l, d) = (40usize, 16usize);
        let qm = rand_mat(&mut rng, l, d);
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let whole = flash_pwl(&qm, &km, &vm, l, l, 8);
        let ragged = flash_pwl_masked(&qm, &km, &vm, 64, 64, 8, MaskKind::None);
        assert_eq!(whole.data, ragged.data, "oversized ragged tile == whole tile");
    }

    #[test]
    fn key_padding_mask_is_bitwise_exact_vs_unpadded() {
        // The tentpole exactness claim at the numerics layer: zero-pad
        // K/V rows beyond `valid`, stamp PaddingKeys, and the valid
        // output rows are bitwise those of the unpadded run — the old
        // residual-softmax-weight approximation is gone.  Ragged tiling
        // makes the padded and unpadded runs tile identically.
        let mut rng = SplitMix64::new(34);
        for &(l, bucket, bc) in &[(100usize, 128usize, 128usize), (37, 64, 16), (150, 256, 128)] {
            let d = 16;
            let qm = rand_mat(&mut rng, l, d);
            let km = rand_mat(&mut rng, l, d);
            let vm = rand_mat(&mut rng, l, d);
            let pad = |m: &Mat| {
                let mut data = m.data.clone();
                data.resize(bucket * d, 0.0);
                Mat::new(bucket, d, data)
            };
            for mask in [MaskKind::None, MaskKind::Causal] {
                let want = flash_pwl_masked(&qm, &km, &vm, bc, bc, 8, mask);
                // Padded run: padded *keys* masked out (None becomes
                // PaddingKeys; causal already excludes them for every
                // real query row), padded query rows sliced away.
                let padded_mask = match mask {
                    MaskKind::None => MaskKind::PaddingKeys { valid: l },
                    m => m,
                };
                let got =
                    flash_pwl_masked(&pad(&qm), &pad(&km), &pad(&vm), bc, bc, 8, padded_mask);
                assert_eq!(
                    &got.data[..l * d],
                    &want.data[..],
                    "L={l} bucket={bucket} bc={bc} {mask:?}: padding changed the numerics"
                );
            }
        }
    }

    #[test]
    fn causal_flash_exact_matches_causal_dense() {
        let mut rng = SplitMix64::new(35);
        let (l, d) = (64usize, 16usize);
        let qm = rand_mat(&mut rng, l, d);
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let dense = sdpa_masked(&qm, &km, &vm, MaskKind::Causal);
        // Row 0 attends only key 0: softmax weight 1 on V row 0.
        for h in 0..d {
            assert!((dense.at(0, h) - vm.at(0, h)).abs() < 1e-6);
        }
        let flash =
            flash_forward_masked(&qm, &km, &vm, 8, 16, &Exp2::Exact, Precision::F32, MaskKind::Causal);
        assert!(mat_error(&flash, &dense).max_abs < 1e-5);
    }

    #[test]
    fn fully_masked_rows_are_zero() {
        let mut rng = SplitMix64::new(36);
        let (l, d) = (16usize, 8usize);
        let qm = rand_mat(&mut rng, l, d);
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let mask = MaskKind::PaddingKeys { valid: 0 };
        assert!(sdpa_masked(&qm, &km, &vm, mask).data.iter().all(|&x| x == 0.0));
        let flash = flash_pwl_masked(&qm, &km, &vm, 8, 8, 8, mask);
        assert!(flash.data.iter().all(|&x| x == 0.0), "no NaN from 0/0");
    }

    /// Split a key sequence into `n` even chunks and return the per-chunk
    /// partials (the host-side oracle of sequence-parallel serving).
    #[allow(clippy::too_many_arguments)]
    fn chunked_partials(
        qm: &Mat,
        km: &Mat,
        vm: &Mat,
        bc: usize,
        exp2: &Exp2,
        prec: Precision,
        mask: MaskKind,
        n: usize,
    ) -> Vec<FlashPartial> {
        let lk = km.rows;
        let w = lk.div_ceil(n).max(1);
        let mut parts = Vec::new();
        let mut start = 0;
        while start < lk {
            let len = w.min(lk - start);
            let slice = |m: &Mat| {
                Mat::new(len, m.cols, m.data[start * m.cols..(start + len) * m.cols].to_vec())
            };
            parts.push(flash_forward_partial(
                qm, &slice(km), &slice(vm), bc, bc, exp2, prec, mask, start, lk,
            ));
            start += len;
        }
        parts
    }

    #[test]
    fn seq_chunked_merge_matches_reference_across_shapes_and_modes() {
        // Tentpole numerics: K/V chunked into 2 and 4 sequence shards,
        // each chunk's partial computed independently, merged in chunk
        // order — parity with masked dense SDPA in every numerics mode,
        // and (exact exp2) tight agreement with the unchunked kernel.
        let mut rng = SplitMix64::new(71);
        for &(l, d, bc) in &[(64usize, 16usize, 8usize), (48, 8, 16), (96, 32, 16)] {
            let qm = rand_mat(&mut rng, l, d);
            let km = rand_mat(&mut rng, l, d);
            let vm = rand_mat(&mut rng, l, d);
            for mask in [MaskKind::None, MaskKind::Causal, MaskKind::PaddingKeys { valid: l - 5 }] {
                let dense = sdpa_masked(&qm, &km, &vm, mask);
                for n in [2usize, 4] {
                    for (exp2, prec, mae, max_abs) in [
                        (Exp2::Exact, Precision::F32, 2e-5, 2e-5),
                        (Exp2::Pwl(PwlExp2::new(8)), Precision::F32, 3e-2, 3e-1),
                        (Exp2::PwlF16(PwlExp2::new(8)), Precision::F16F32, 3e-2, 3e-1),
                        (Exp2::PwlF16(PwlExp2::new(4)), Precision::F16F32, 6e-2, 6e-1),
                    ] {
                        let parts = chunked_partials(&qm, &km, &vm, bc, &exp2, prec, mask, n);
                        let merged = merge_partials(&parts, &exp2);
                        let err = mat_error(&merged, &dense);
                        assert!(
                            err.mae < mae && err.max_abs < max_abs,
                            "L={l} d={d} bc={bc} n={n} {mask:?}: {err:?}"
                        );
                        assert!(merged.data.iter().all(|x| x.is_finite()));
                        if matches!(exp2, Exp2::Exact) {
                            // Exact exp2: the only divergence from the
                            // unchunked kernel is FP reassociation at the
                            // chunk seams.
                            let whole = flash_forward_masked(
                                &qm, &km, &vm, bc, bc, &exp2, prec, mask,
                            );
                            assert!(mat_error(&merged, &whole).max_abs < 1e-5);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn single_chunk_merge_is_bitwise_the_plain_kernel() {
        // Satellite: one chunk covering the whole key range, adopted by
        // the merge and normalized, must be operation-for-operation the
        // plain kernel — for every mask kind.
        let mut rng = SplitMix64::new(72);
        let (l, d, bc) = (40usize, 16usize, 16usize);
        let qm = rand_mat(&mut rng, l, d);
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let exp2 = Exp2::PwlF16(PwlExp2::new(8));
        for mask in [MaskKind::None, MaskKind::Causal, MaskKind::PaddingKeys { valid: 7 }] {
            let part = flash_forward_partial(
                &qm, &km, &vm, bc, bc, &exp2, Precision::F16F32, mask, 0, l,
            );
            let merged = merge_partials(&[part], &exp2);
            let whole = flash_pwl_masked(&qm, &km, &vm, bc, bc, 8, mask);
            assert_eq!(merged.data, whole.data, "{mask:?}");
        }
    }

    #[test]
    fn resumed_partial_rows_are_bitwise_the_whole_run_suffix() {
        // Tentpole pin (DESIGN.md §11): a resumed prefill computing only
        // the suffix query rows at their global coordinates must be
        // bitwise the corresponding rows of the cold whole-query run —
        // for every mask kind, aligned and ragged resume points, row
        // tilings that re-block the suffix differently from the cold
        // run, and both whole-range and ragged-chunked keys.
        let mut rng = SplitMix64::new(74);
        let (l, d) = (48usize, 16usize);
        let qm = rand_mat(&mut rng, l, d);
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let exp2 = Exp2::PwlF16(PwlExp2::new(8));
        for mask in [MaskKind::None, MaskKind::Causal, MaskKind::PaddingKeys { valid: 19 }] {
            for (br, bc) in [(8usize, 8usize), (16, 8), (8, 16)] {
                for resume in [1usize, 8, 17, 32, l - 1] {
                    let rows = l - resume;
                    let tag = format!("{mask:?} br={br} bc={bc} resume={resume}");
                    let qs = Mat::new(rows, d, qm.data[resume * d..].to_vec());
                    // Whole key range: the finalized resumed rows are
                    // the cold kernel's suffix rows, bit for bit.
                    let cold = flash_pwl_masked(&qm, &km, &vm, br, bc, 8, mask);
                    let warm = flash_forward_partial_at(
                        &qs, &km, &vm, br, bc, &exp2, Precision::F16F32, mask, resume, 0, l,
                    )
                    .finalize();
                    assert_eq!(warm.data, cold.data[resume * d..], "whole {tag}");
                    // Ragged key chunks: per-chunk resumed partials
                    // merged in chunk order equal the cold chunked
                    // run's suffix rows (the seq_shards > 1 warm path).
                    let split = 20usize;
                    let k0m = Mat::new(split, d, km.data[..split * d].to_vec());
                    let v0m = Mat::new(split, d, vm.data[..split * d].to_vec());
                    let k1m = Mat::new(l - split, d, km.data[split * d..].to_vec());
                    let v1m = Mat::new(l - split, d, vm.data[split * d..].to_vec());
                    let cold_chunked = merge_partials(
                        &[
                            flash_pwl_partial(&qm, &k0m, &v0m, br, bc, 8, mask, 0, l),
                            flash_pwl_partial(&qm, &k1m, &v1m, br, bc, 8, mask, split, l),
                        ],
                        &exp2,
                    );
                    let warm_chunked = merge_partials(
                        &[
                            flash_pwl_resumed(&qs, &k0m, &v0m, br, bc, 8, mask, resume, 0, l),
                            flash_pwl_resumed(&qs, &k1m, &v1m, br, bc, 8, mask, resume, split, l),
                        ],
                        &exp2,
                    );
                    assert_eq!(
                        warm_chunked.data,
                        cold_chunked.data[resume * d..],
                        "chunked {tag}"
                    );
                }
            }
        }
    }

    #[test]
    fn merging_a_fully_masked_partial_is_the_identity() {
        // Satellite: a zero-`l` partial (its chunk fully masked for
        // every row) must leave the running state bitwise untouched, in
        // either merge position.
        let mut rng = SplitMix64::new(73);
        let (l, d, bc) = (32usize, 8usize, 8usize);
        let qm = rand_mat(&mut rng, l, d);
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let exp2 = Exp2::PwlF16(PwlExp2::new(8));
        let live = flash_forward_partial(
            &qm, &km, &vm, bc, bc, &exp2, Precision::F16F32, MaskKind::None, 0, 2 * l,
        );
        // The second half of a PaddingKeys{valid: l} sequence is fully
        // masked: its partial must be all-zero state.
        let masked = flash_forward_partial(
            &qm, &km, &vm, bc, bc, &exp2, Precision::F16F32,
            MaskKind::PaddingKeys { valid: l }, l, 2 * l,
        );
        assert!(masked.l.iter().all(|&x| x == 0.0));
        assert!(masked.acc.iter().all(|&x| x == 0.0));

        let mut state = live.clone();
        state.merge_from(&masked, &exp2);
        assert_eq!(state, live, "zero-l merge must be the identity");
        // And in front: adopting after a skipped chunk equals adopting
        // directly.
        let mut front = FlashPartial::empty(l, d);
        front.merge_from(&masked, &exp2);
        front.merge_from(&live, &exp2);
        assert_eq!(front, live);
    }

    #[test]
    fn merge_order_is_pinned_to_chunk_order_not_tree_order() {
        // Satellite: the contract is the sequential fold over chunk
        // order 0..n.  Tree-order merging is a different FP
        // reassociation — this input is constructed so the two differ
        // in the last ULP deterministically (X just above half an ULP
        // of 1.0: (1+X)+X rounds up twice, 1+(X+X) only once).
        const X: f32 = 6.5e-8;
        let exp2 = Exp2::Exact;
        let part = |l: f32| FlashPartial {
            rows: 1,
            d: 1,
            acc: vec![l],
            m: vec![0.0],
            l: vec![l],
        };
        let fold = |ls: &[f32]| {
            let mut s = FlashPartial::empty(1, 1);
            for &l in ls {
                s.merge_from(&part(l), &exp2);
            }
            s
        };
        let sequential = fold(&[1.0, X, X]);
        // Tree order: (1.0) ⊕ (X ⊕ X).
        let mut tree = fold(&[1.0]);
        tree.merge_from(&fold(&[X, X]), &exp2);
        assert_eq!(sequential.l[0], (1.0f32 + X) + X);
        assert_eq!(tree.l[0], 1.0f32 + (X + X));
        assert_ne!(
            sequential.l[0], tree.l[0],
            "tree-order merge must not be mistaken for the pinned sequential fold"
        );
    }

    #[test]
    fn decode_split_kv_merge_matches_full_row() {
        // Split-KV decode (DESIGN.md §7): partial rows over prefix
        // ranges merged in range order.  The whole-range partial
        // normalized is bitwise the decode kernel, and multi-range
        // merges stay within the Table-2 band of the dense row.
        let mut rng = SplitMix64::new(74);
        let (lk, d, bc) = (96usize, 16usize, 16usize);
        let qr = rng.normal_matrix(1, d);
        let km = rng.normal_matrix(lk, d);
        let vm = rng.normal_matrix(lk, d);
        let exp2 = Exp2::PwlF16(PwlExp2::new(8));

        let whole = flash_decode_row(&qr, &km, &vm, d, bc, &exp2, Precision::F16F32);
        let single = flash_decode_row_partial(&qr, &km, &vm, d, bc, &exp2, Precision::F16F32);
        let inv = 1.0 / single.l[0];
        let normalized: Vec<f32> = single.acc.iter().map(|&a| a * inv).collect();
        assert_eq!(normalized, whole, "whole-range partial == decode kernel");

        let dense = sdpa(
            &Mat::new(1, d, qr.clone()),
            &Mat::new(lk, d, km.clone()),
            &Mat::new(lk, d, vm.clone()),
        );
        for ranges in [vec![(0usize, 48usize), (48, 48)], vec![(0, 24), (24, 24), (48, 48)]] {
            let parts: Vec<FlashPartial> = ranges
                .iter()
                .map(|&(start, len)| {
                    decode_pwl_partial(
                        &qr,
                        &km[start * d..(start + len) * d],
                        &vm[start * d..(start + len) * d],
                        d,
                        bc,
                        8,
                    )
                })
                .collect();
            let merged = merge_partials(&parts, &exp2);
            let err = mat_error(&merged, &dense);
            assert!(err.mae < 3e-2, "{ranges:?}: {err:?}");
            let vs_whole = mat_error(&merged, &Mat::new(1, d, whole.clone()));
            assert!(vs_whole.mae < 3e-2, "{ranges:?}: {vs_whole:?}");
        }
    }

    #[test]
    fn mat_error_basics() {
        let a = Mat::new(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::new(1, 4, vec![1.0, 2.0, 3.0, 5.0]);
        let e = mat_error(&a, &b);
        assert!((e.mae - 0.25).abs() < 1e-12);
        assert!((e.rmse - 0.5).abs() < 1e-12);
        assert!((e.max_abs - 1.0).abs() < 1e-12);
    }
}
