//! Host-side reference attention implementations (row-major f32 matrices).
//!
//! These are the oracles the cycle simulator and the serving path are
//! checked against inside Rust — the same ladder as the Python side:
//! dense SDPA (exact), tiled FlashAttention with exact exp2, and tiled
//! FlashAttention with the PWL exp2 (the strict twin of both the Pallas
//! kernel and the FSA device).

use crate::mask::{MaskKind, TileCoverage};
use crate::numerics::f16::quantize_ftz_f32 as quantize_f32;
use crate::numerics::pwl::PwlExp2;
use crate::numerics::LOG2E;

/// Precision regime of matmul operands (state is always f32).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// Operands quantized to fp16 before each multiply (FSA / Table 1).
    F16F32,
    /// Pure f32 (used by tests against the f32 Pallas path).
    F32,
}

/// Row-major matrix view helpers.
#[derive(Clone, Debug)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Quantize every element through fp16 (activation load on FSA).
    pub fn quantized(&self) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| quantize_f32(x)).collect(),
        }
    }
}

#[inline]
fn q(x: f32, p: Precision) -> f32 {
    match p {
        Precision::F16F32 => quantize_f32(x),
        Precision::F32 => x,
    }
}

/// Dense fp32 SDPA: softmax(Q K^T / sqrt(d)) V.  Exact reference.
pub fn sdpa(qm: &Mat, km: &Mat, vm: &Mat) -> Mat {
    sdpa_masked(qm, km, vm, MaskKind::None)
}

/// Masked dense SDPA: masked `(i, j)` pairs are *excluded* from the
/// softmax (weight exactly zero — not a large-negative approximation),
/// so this is the exact semantic reference for every [`MaskKind`].
/// Rows with no valid keys produce a zero output row by definition.
pub fn sdpa_masked(qm: &Mat, km: &Mat, vm: &Mat, mask: MaskKind) -> Mat {
    let (l, d) = (qm.rows, qm.cols);
    let lk = km.rows;
    assert_eq!(km.cols, d);
    assert_eq!(vm.rows, lk);
    let scale = 1.0 / (d as f64).sqrt();
    let mut out = Mat::zeros(l, vm.cols);
    let mut row = vec![0.0f64; lk];
    for i in 0..l {
        // Valid keys are a prefix (see MaskKind::valid_keys).
        let vk = mask.valid_keys(i, lk);
        if vk == 0 {
            continue; // fully-masked row: zero output
        }
        let mut maxv = f64::NEG_INFINITY;
        for j in 0..vk {
            let mut s = 0.0f64;
            for k in 0..d {
                s += qm.at(i, k) as f64 * km.at(j, k) as f64;
            }
            let s = s * scale;
            row[j] = s;
            maxv = maxv.max(s);
        }
        let mut denom = 0.0f64;
        for j in 0..vk {
            row[j] = (row[j] - maxv).exp();
            denom += row[j];
        }
        for h in 0..vm.cols {
            let mut acc = 0.0f64;
            for j in 0..vk {
                acc += row[j] * vm.at(j, h) as f64;
            }
            out.set(i, h, (acc / denom) as f32);
        }
    }
    out
}

/// exp2 evaluator used by the flash reference.
pub enum Exp2 {
    Exact,
    /// PWL computed in f32 (the f32 Pallas path).
    Pwl(PwlExp2),
    /// PWL with the interpolation MAC in fp16 — the PE datapath.
    PwlF16(PwlExp2),
}

impl Exp2 {
    #[inline]
    fn eval(&self, x: f32) -> f32 {
        match self {
            Exp2::Exact => x.exp2(),
            Exp2::Pwl(p) => p.eval_f32(x),
            Exp2::PwlF16(p) => p.eval_f16_mac(x),
        }
    }
}

/// Tiled FlashAttention-2 forward, Algorithm 1 of the paper, with either
/// exact or PWL exp2 and fp16-or-f32 matmul operands.  Bit-order faithful:
/// the first matmul accumulates over k descending (the upward systolic
/// path sums from the bottom row up), rowsum and PV accumulate over n
/// ascending (downward path).  Exact tiling required (the original API);
/// [`flash_forward_masked`] additionally supports masks and ragged final
/// tiles.
pub fn flash_forward(
    qm: &Mat,
    km: &Mat,
    vm: &Mat,
    br: usize,
    bc: usize,
    exp2: &Exp2,
    prec: Precision,
) -> Mat {
    assert!(
        qm.rows % br == 0 && km.rows % bc == 0,
        "tile sizes must divide seq lens"
    );
    flash_forward_masked(qm, km, vm, br, bc, exp2, prec, MaskKind::None)
}

/// Masked tiled FlashAttention with the tile-skipping schedule
/// (DESIGN.md §6).  Generalizes [`flash_forward`]:
///
/// * **Mask before the update.**  Within each tile the mask is applied
///   *before* the online-softmax update: masked lanes are excluded from
///   the tile row-max and their stored P is zeroed (the device's
///   element-wise mask wave), so the paper's FP operation order over the
///   valid lanes is untouched — masking is exact, not a large-negative
///   approximation.
/// * **Tile skipping.**  A fully-masked tile is skipped outright; a row
///   with no valid key in a tile leaves its `(m, l, O)` state untouched.
///   Both are exact because a fully-masked tile/row contributes nothing
///   to any online-softmax state (legality argument in DESIGN.md §6).
///   For causal this drops the whole upper triangle — ≈2× fewer tiles.
/// * **Ragged tiles.**  The final row/column tile may be short (same
///   rule as [`flash_decode_row`]), so any sequence length tiles at the
///   array size.  With exact tiling and `MaskKind::None` the arithmetic
///   is operation-for-operation that of the original kernel.
/// * **Fully-masked rows** (no valid key anywhere) produce a zero output
///   row by definition (their `l` stays 0, which would otherwise 0/0).
#[allow(clippy::too_many_arguments)]
pub fn flash_forward_masked(
    qm: &Mat,
    km: &Mat,
    vm: &Mat,
    br: usize,
    bc: usize,
    exp2: &Exp2,
    prec: Precision,
    mask: MaskKind,
) -> Mat {
    let (l, d) = (qm.rows, qm.cols);
    let lk = km.rows;
    assert_eq!(km.cols, d);
    assert_eq!(vm.rows, lk);
    assert!(br >= 1 && bc >= 1, "tile sizes must be >= 1");
    let scale = (LOG2E / (d as f64).sqrt()) as f32;

    let mut out = Mat::zeros(l, d);
    let mut s = vec![0.0f32; br * bc];
    let mut p16 = vec![0.0f32; br * bc];

    // Quantization is idempotent: pre-quantize the operands once instead
    // of per-MAC inside the O(L^2 d) loops (EXPERIMENTS.md §Perf).
    let (qq, kq, vq) = match prec {
        Precision::F16F32 => (qm.quantized(), km.quantized(), vm.quantized()),
        Precision::F32 => (qm.clone(), km.clone(), vm.clone()),
    };
    let (qm, km, vm) = (&qq, &kq, &vq);

    // Finite -inf stand-in (same convention as the Pallas kernel): a true
    // -inf would feed NaN through the Split unit's `x - ceil(x)`.
    const NEG_INF: f32 = -1e30;
    let mut q0 = 0;
    while q0 < l {
        let bre = br.min(l - q0);
        let mut m = vec![NEG_INF; bre];
        let mut lsum = vec![0.0f32; bre];
        let mut acc = vec![0.0f32; bre * d];
        let mut k0 = 0;
        while k0 < lk {
            let bce = bc.min(lk - k0);
            // Tile-skipping schedule: a fully-masked tile touches no row
            // state, so skipping it is exact.
            if mask.coverage(q0, bre, k0, bce) == TileCoverage::Empty {
                k0 += bce;
                continue;
            }
            for r in 0..bre {
                // Valid keys form a per-row prefix of the tile's columns
                // (both mask kinds are column-prefix masks).
                let vc = mask.valid_keys(q0 + r, lk).saturating_sub(k0).min(bce);
                if vc == 0 {
                    continue; // row fully masked in this tile: state untouched
                }
                // S = Q K^T, fp32 psums, k-descending accumulation order
                // (upward path starts at the bottom row of the array).
                let qrow = &qm.data[(q0 + r) * d..(q0 + r + 1) * d];
                for c in 0..vc {
                    let krow = &km.data[(k0 + c) * d..(k0 + c + 1) * d];
                    let mut ps = 0.0f32;
                    for k in (0..d).rev() {
                        ps += qrow[k] * krow[k];
                    }
                    s[r * bc + c] = ps;
                }
                // The device parks S in fp16 result registers; rowmax and
                // the whole elementwise chain run on those values, and the
                // rowsum sums the *stored* (quantized, flushed) P.  Masked
                // lanes are excluded from the rowmax and their P is zeroed
                // (the mask wave) before the rowsum.
                let mut local_m = f32::NEG_INFINITY;
                for c in 0..vc {
                    s[r * bc + c] = q(s[r * bc + c], prec);
                    local_m = local_m.max(s[r * bc + c]);
                }
                let new_m = m[r].max(local_m);
                let b = exp2.eval(scale * (m[r] - new_m));
                let mut local_l = 0.0f32;
                for c in 0..vc {
                    let nv = q(s[r * bc + c] - new_m, prec);
                    let pv = exp2.eval(q(scale * nv, prec));
                    p16[r * bc + c] = q(pv, prec);
                    local_l += p16[r * bc + c];
                }
                for c in vc..bce {
                    p16[r * bc + c] = 0.0;
                    local_l += p16[r * bc + c];
                }
                lsum[r] = lsum[r] * b + local_l;
                m[r] = new_m;
                // Rescale the accumulator (diag(b) old_O) now; PV adds in
                // the n-ascending loop below.
                for h in 0..d {
                    acc[r * d + h] *= b;
                }
            }
            // O += P V, n-ascending (downward path, top row first); the
            // masked lanes ride along with P = 0, exactly as on the array.
            for r in 0..bre {
                if mask.valid_keys(q0 + r, lk) <= k0 {
                    continue; // row skipped above: stale P, state untouched
                }
                for h in 0..d {
                    let mut ps = 0.0f32;
                    for n in 0..bce {
                        ps += p16[r * bc + n] * vm.at(k0 + n, h);
                    }
                    acc[r * d + h] += ps;
                }
            }
            k0 += bce;
        }
        for r in 0..bre {
            if lsum[r] == 0.0 {
                continue; // fully-masked row: defined zero output
            }
            let inv = 1.0 / lsum[r];
            for h in 0..d {
                out.set(q0 + r, h, acc[r * d + h] * inv);
            }
        }
        q0 += bre;
    }
    out
}

/// Single-query-row FlashAttention over a `(len, d)` K/V prefix — the
/// decode-phase kernel (DESIGN.md §5).
///
/// This is the `br = 1` degeneration of [`flash_forward`], streaming
/// the prefix in column tiles of `bc` tokens (a ragged final tile is
/// allowed, so any prefix length works — decode prefixes grow by one
/// token per step).  Every quantization point matches the prefill
/// path: fp32 psums over quantized operands, fp16 parking of S, the
/// PWL exp2 on the quantized argument, fp16 storage of P, and the
/// same accumulation orders (k-descending first matmul, n-ascending
/// rowsum/PV).  When `bc` divides `len` the output is **bitwise
/// identical** to `flash_forward` with `br = 1` on the same inputs
/// (pinned by a unit test) — which is exactly what makes cached
/// decode, miss-path recompute, and stateless full-prefix
/// recomputation agree bit-for-bit in the serving e2e tests.
///
/// Stateless recompute and the cached path both call this function —
/// the cache changes where the K/V bytes come from (device pages vs
/// host tier) and what the step costs, never the numerics.
pub fn flash_decode_row(
    qr: &[f32],
    km: &[f32],
    vm: &[f32],
    d: usize,
    bc: usize,
    exp2: &Exp2,
    prec: Precision,
) -> Vec<f32> {
    assert!(d >= 1 && bc >= 1);
    assert_eq!(qr.len(), d, "q must be one (1, d) row");
    assert_eq!(km.len() % d, 0, "K must be (len, d) row-major");
    assert_eq!(km.len(), vm.len(), "K and V must agree");
    let lk = km.len() / d;
    assert!(lk >= 1, "need at least one prefix token");
    let scale = (LOG2E / (d as f64).sqrt()) as f32;

    let qq: Vec<f32> = qr.iter().map(|&x| q(x, prec)).collect();
    let kq: Vec<f32> = km.iter().map(|&x| q(x, prec)).collect();
    let vq: Vec<f32> = vm.iter().map(|&x| q(x, prec)).collect();

    const NEG_INF: f32 = -1e30;
    let mut m = NEG_INF;
    let mut lsum = 0.0f32;
    let mut acc = vec![0.0f32; d];
    let mut s = vec![0.0f32; bc];
    let mut p16 = vec![0.0f32; bc];

    let mut k0 = 0;
    while k0 < lk {
        let bce = bc.min(lk - k0);
        for c in 0..bce {
            let krow = &kq[(k0 + c) * d..(k0 + c + 1) * d];
            let mut ps = 0.0f32;
            for k in (0..d).rev() {
                ps += qq[k] * krow[k];
            }
            s[c] = ps;
        }
        let mut local_m = f32::NEG_INFINITY;
        for c in 0..bce {
            s[c] = q(s[c], prec);
            local_m = local_m.max(s[c]);
        }
        let new_m = m.max(local_m);
        let b = exp2.eval(scale * (m - new_m));
        let mut local_l = 0.0f32;
        for c in 0..bce {
            let nv = q(s[c] - new_m, prec);
            let pv = exp2.eval(q(scale * nv, prec));
            p16[c] = q(pv, prec);
            local_l += p16[c];
        }
        lsum = lsum * b + local_l;
        m = new_m;
        for a in acc.iter_mut() {
            *a *= b;
        }
        for (h, a) in acc.iter_mut().enumerate() {
            let mut ps = 0.0f32;
            for n in 0..bce {
                ps += p16[n] * vq[(k0 + n) * d + h];
            }
            *a += ps;
        }
        k0 += bce;
    }
    let inv = 1.0 / lsum;
    acc.iter().map(|&a| a * inv).collect()
}

/// Convenience: the decode row with the paper's device numerics (PWL
/// exp2, fp16 operand quantization) — the strict twin the device
/// workers' reference backend runs for decode shards.
pub fn decode_pwl(qr: &[f32], km: &[f32], vm: &[f32], d: usize, bc: usize, segments: usize) -> Vec<f32> {
    flash_decode_row(
        qr, km, vm, d, bc,
        &Exp2::PwlF16(PwlExp2::new(segments)),
        Precision::F16F32,
    )
}

/// Convenience: PWL flash with the paper's defaults (used as the
/// device-numerics oracle everywhere in the Rust tests).
pub fn flash_pwl(qm: &Mat, km: &Mat, vm: &Mat, br: usize, bc: usize, segments: usize) -> Mat {
    flash_forward(
        qm, km, vm, br, bc,
        &Exp2::PwlF16(PwlExp2::new(segments)),
        Precision::F16F32,
    )
}

/// Convenience: masked PWL flash with the paper's device numerics —
/// the strict twin the device workers' reference backend runs for
/// masked shards (ragged tiling allowed, see [`flash_forward_masked`]).
pub fn flash_pwl_masked(
    qm: &Mat,
    km: &Mat,
    vm: &Mat,
    br: usize,
    bc: usize,
    segments: usize,
    mask: MaskKind,
) -> Mat {
    flash_forward_masked(
        qm, km, vm, br, bc,
        &Exp2::PwlF16(PwlExp2::new(segments)),
        Precision::F16F32,
        mask,
    )
}

/// Error statistics between two equally-shaped matrices (Table 2 metrics).
#[derive(Clone, Copy, Debug, Default)]
pub struct MatError {
    pub mae: f64,
    pub rmse: f64,
    pub mre: f64,
    pub max_abs: f64,
}

pub fn mat_error(got: &Mat, want: &Mat) -> MatError {
    assert_eq!(got.rows, want.rows);
    assert_eq!(got.cols, want.cols);
    let n = got.data.len();
    let (mut abs_sum, mut sq_sum, mut rel_sum, mut max_abs) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for i in 0..n {
        let g = got.data[i] as f64;
        let w = want.data[i] as f64;
        let abs = (g - w).abs();
        abs_sum += abs;
        sq_sum += abs * abs;
        // Paper MRE convention: |err| / (|ref| + eps) with eps guarding
        // zero outputs (attention outputs are rarely exactly zero).
        rel_sum += abs / (w.abs() + 1e-9);
        max_abs = max_abs.max(abs);
    }
    MatError {
        mae: abs_sum / n as f64,
        rmse: (sq_sum / n as f64).sqrt(),
        mre: rel_sum / n as f64,
        max_abs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::rng::SplitMix64;

    fn rand_mat(rng: &mut SplitMix64, rows: usize, cols: usize) -> Mat {
        Mat::new(rows, cols, rng.normal_matrix(rows, cols))
    }

    #[test]
    fn flash_exact_matches_dense_sdpa() {
        let mut rng = SplitMix64::new(5);
        let (l, d) = (32, 16);
        let qm = rand_mat(&mut rng, l, d);
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let dense = sdpa(&qm, &km, &vm);
        let flash = flash_forward(&qm, &km, &vm, 8, 8, &Exp2::Exact, Precision::F32);
        let err = mat_error(&flash, &dense);
        assert!(err.max_abs < 1e-5, "{err:?}");
    }

    #[test]
    fn flash_pwl_close_to_dense() {
        let mut rng = SplitMix64::new(6);
        let (l, d) = (32, 16);
        let qm = rand_mat(&mut rng, l, d);
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let dense = sdpa(&qm, &km, &vm);
        let flash = flash_pwl(&qm, &km, &vm, 8, 8, 8);
        let err = mat_error(&flash, &dense);
        // PWL + fp16 operand error budget (paper Table 2 scale).
        assert!(err.mae < 2e-2, "{err:?}");
        assert!(err.max_abs < 2e-1, "{err:?}");
    }

    #[test]
    fn tile_shape_independence_with_exact_exp2() {
        let mut rng = SplitMix64::new(8);
        let (l, d) = (64, 16);
        let qm = rand_mat(&mut rng, l, d);
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let a = flash_forward(&qm, &km, &vm, 8, 16, &Exp2::Exact, Precision::F32);
        let b = flash_forward(&qm, &km, &vm, 32, 32, &Exp2::Exact, Precision::F32);
        assert!(mat_error(&a, &b).max_abs < 1e-5);
    }

    #[test]
    fn huge_logits_stay_finite() {
        let mut rng = SplitMix64::new(9);
        let (l, d) = (16, 8);
        let mut qm = rand_mat(&mut rng, l, d);
        for v in qm.data.iter_mut() {
            *v *= 50.0;
        }
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let out = flash_pwl(&qm, &km, &vm, 8, 8, 8);
        assert!(out.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn decode_row_is_bitwise_flash_forward_br1() {
        // When bc divides the prefix length, the decode kernel must be
        // bit-for-bit the br=1 tiled flash — the invariant the serving
        // e2e leans on (cached vs recompute vs stateless all agree).
        // flash_decode_row intentionally duplicates flash_forward's
        // inner loop (the original asserts exact tiling); this sweep is
        // the lockstep guard — any change to either kernel's
        // accumulation order or quantization points must keep it green.
        let mut rng = SplitMix64::new(11);
        for (case, &(lk, d, bc)) in
            [(32usize, 16usize, 8usize), (24, 8, 24), (64, 32, 16), (16, 16, 4), (128, 64, 32)]
                .iter()
                .enumerate()
        {
            let qr = rng.normal_matrix(1, d);
            let km = rng.normal_matrix(lk, d);
            let vm = rng.normal_matrix(lk, d);
            for (exp2, prec) in [
                (Exp2::Exact, Precision::F32),
                (Exp2::Pwl(PwlExp2::new(8)), Precision::F32),
                (Exp2::PwlF16(PwlExp2::new(8)), Precision::F16F32),
                (Exp2::PwlF16(PwlExp2::new(4)), Precision::F16F32),
            ] {
                let row = flash_decode_row(&qr, &km, &vm, d, bc, &exp2, prec);
                let full = flash_forward(
                    &Mat::new(1, d, qr.clone()),
                    &Mat::new(lk, d, km.clone()),
                    &Mat::new(lk, d, vm.clone()),
                    1,
                    bc,
                    &exp2,
                    prec,
                );
                assert_eq!(
                    row, full.data,
                    "case {case} (lk={lk} d={d} bc={bc}): decode row diverged from flash br=1"
                );
            }
        }
    }

    #[test]
    fn decode_row_matches_dense_sdpa_row() {
        // Ragged prefix (not a multiple of bc): still a valid decode.
        let mut rng = SplitMix64::new(12);
        let (lk, d, bc) = (37usize, 16usize, 8usize);
        let qr = rng.normal_matrix(1, d);
        let km = rng.normal_matrix(lk, d);
        let vm = rng.normal_matrix(lk, d);
        let row = flash_decode_row(&qr, &km, &vm, d, bc, &Exp2::Exact, Precision::F32);
        let dense = sdpa(
            &Mat::new(1, d, qr.clone()),
            &Mat::new(lk, d, km.clone()),
            &Mat::new(lk, d, vm.clone()),
        );
        let err = mat_error(&Mat::new(1, d, row.clone()), &dense);
        assert!(err.max_abs < 1e-5, "{err:?}");
        // And the PWL+fp16 twin stays inside the Table-2 error band.
        let pwl = decode_pwl(&qr, &km, &vm, d, bc, 8);
        let err = mat_error(&Mat::new(1, d, pwl), &dense);
        assert!(err.mae < 2e-2, "{err:?}");
        assert!(row.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn masked_flash_matches_masked_dense_across_shapes_and_modes() {
        // Satellite coverage: masked flash vs masked dense parity across
        // shapes x numerics modes.  Exact exp2/f32 pins tight; the PWL +
        // fp16 modes stay inside the Table-2 error band.
        let mut rng = SplitMix64::new(31);
        for &(l, d, br, bc) in &[(32usize, 16usize, 8usize, 8usize), (48, 8, 16, 8), (40, 16, 16, 16), (64, 32, 32, 16)]
        {
            let qm = rand_mat(&mut rng, l, d);
            let km = rand_mat(&mut rng, l, d);
            let vm = rand_mat(&mut rng, l, d);
            for mask in [
                MaskKind::Causal,
                MaskKind::PaddingKeys { valid: l - 7 },
                MaskKind::PaddingKeys { valid: 3 },
                MaskKind::None,
            ] {
                let dense = sdpa_masked(&qm, &km, &vm, mask);
                for (exp2, prec, mae, max_abs) in [
                    (Exp2::Exact, Precision::F32, 1e-5, 1e-5),
                    (Exp2::Pwl(PwlExp2::new(8)), Precision::F32, 2e-2, 2e-1),
                    (Exp2::PwlF16(PwlExp2::new(8)), Precision::F16F32, 2e-2, 2e-1),
                    (Exp2::PwlF16(PwlExp2::new(4)), Precision::F16F32, 5e-2, 5e-1),
                ] {
                    let flash = flash_forward_masked(&qm, &km, &vm, br, bc, &exp2, prec, mask);
                    let err = mat_error(&flash, &dense);
                    assert!(
                        err.mae < mae && err.max_abs < max_abs,
                        "L={l} d={d} br={br} bc={bc} {mask:?}: {err:?}"
                    );
                    assert!(flash.data.iter().all(|x| x.is_finite()));
                }
            }
        }
    }

    #[test]
    fn masked_flash_with_none_is_bitwise_the_original_kernel() {
        // The masked kernel with MaskKind::None and exact tiling must be
        // operation-for-operation the original flash_forward (which now
        // delegates) — pinned against the independently-implemented
        // decode kernel via the br=1 lockstep test below, and here
        // against ragged whole-tile degeneration: one ragged tile of
        // size lk equals one exact tile of size lk.
        let mut rng = SplitMix64::new(33);
        let (l, d) = (40usize, 16usize);
        let qm = rand_mat(&mut rng, l, d);
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let whole = flash_pwl(&qm, &km, &vm, l, l, 8);
        let ragged = flash_pwl_masked(&qm, &km, &vm, 64, 64, 8, MaskKind::None);
        assert_eq!(whole.data, ragged.data, "oversized ragged tile == whole tile");
    }

    #[test]
    fn key_padding_mask_is_bitwise_exact_vs_unpadded() {
        // The tentpole exactness claim at the numerics layer: zero-pad
        // K/V rows beyond `valid`, stamp PaddingKeys, and the valid
        // output rows are bitwise those of the unpadded run — the old
        // residual-softmax-weight approximation is gone.  Ragged tiling
        // makes the padded and unpadded runs tile identically.
        let mut rng = SplitMix64::new(34);
        for &(l, bucket, bc) in &[(100usize, 128usize, 128usize), (37, 64, 16), (150, 256, 128)] {
            let d = 16;
            let qm = rand_mat(&mut rng, l, d);
            let km = rand_mat(&mut rng, l, d);
            let vm = rand_mat(&mut rng, l, d);
            let pad = |m: &Mat| {
                let mut data = m.data.clone();
                data.resize(bucket * d, 0.0);
                Mat::new(bucket, d, data)
            };
            for mask in [MaskKind::None, MaskKind::Causal] {
                let want = flash_pwl_masked(&qm, &km, &vm, bc, bc, 8, mask);
                // Padded run: padded *keys* masked out (None becomes
                // PaddingKeys; causal already excludes them for every
                // real query row), padded query rows sliced away.
                let padded_mask = match mask {
                    MaskKind::None => MaskKind::PaddingKeys { valid: l },
                    m => m,
                };
                let got =
                    flash_pwl_masked(&pad(&qm), &pad(&km), &pad(&vm), bc, bc, 8, padded_mask);
                assert_eq!(
                    &got.data[..l * d],
                    &want.data[..],
                    "L={l} bucket={bucket} bc={bc} {mask:?}: padding changed the numerics"
                );
            }
        }
    }

    #[test]
    fn causal_flash_exact_matches_causal_dense() {
        let mut rng = SplitMix64::new(35);
        let (l, d) = (64usize, 16usize);
        let qm = rand_mat(&mut rng, l, d);
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let dense = sdpa_masked(&qm, &km, &vm, MaskKind::Causal);
        // Row 0 attends only key 0: softmax weight 1 on V row 0.
        for h in 0..d {
            assert!((dense.at(0, h) - vm.at(0, h)).abs() < 1e-6);
        }
        let flash =
            flash_forward_masked(&qm, &km, &vm, 8, 16, &Exp2::Exact, Precision::F32, MaskKind::Causal);
        assert!(mat_error(&flash, &dense).max_abs < 1e-5);
    }

    #[test]
    fn fully_masked_rows_are_zero() {
        let mut rng = SplitMix64::new(36);
        let (l, d) = (16usize, 8usize);
        let qm = rand_mat(&mut rng, l, d);
        let km = rand_mat(&mut rng, l, d);
        let vm = rand_mat(&mut rng, l, d);
        let mask = MaskKind::PaddingKeys { valid: 0 };
        assert!(sdpa_masked(&qm, &km, &vm, mask).data.iter().all(|&x| x == 0.0));
        let flash = flash_pwl_masked(&qm, &km, &vm, 8, 8, 8, mask);
        assert!(flash.data.iter().all(|&x| x == 0.0), "no NaN from 0/0");
    }

    #[test]
    fn mat_error_basics() {
        let a = Mat::new(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::new(1, 4, vec![1.0, 2.0, 3.0, 5.0]);
        let e = mat_error(&a, &b);
        assert!((e.mae - 0.25).abs() < 1e-12);
        assert!((e.rmse - 0.5).abs() < 1e-12);
        assert!((e.max_abs - 1.0).abs() < 1e-12);
    }
}
