//! Piecewise-linear exp2 — the bit-level contract of the FSA Split unit
//! plus MAC interpolation (paper §3.3).
//!
//! FlashAttention only evaluates `exp2(x)` for `x <= 0`.  Splitting
//! `x = xi + xf` with `xi = ceil(x)` puts the fraction in `(-1, 0]`, so
//! `2^xf ∈ (0.5, 1]` and an S-piece uniform PWL over that interval,
//! evaluated on the PE's MAC, approximates it; `2^xi` is a pure exponent
//! adjustment.  Coefficients here use the same endpoint-interpolation
//! formula as `python/compile/kernels/pwl.py` and are golden-tested
//! against `artifacts/pwl_coeffs_*.txt`.

use crate::numerics::f16::{negative_normals, F16};

/// One PWL approximation of exp2 on (-inf, 0] with `segments` pieces.
#[derive(Clone, Debug)]
pub struct PwlExp2 {
    pub segments: usize,
    pub slopes: Vec<f64>,
    pub intercepts: Vec<f64>,
}

/// Rounding / evaluation mode for the error sweeps (Fig. 12 reproduces the
/// paper's sweep; the extra modes quantify each quantization choice the
/// paper leaves implicit — see EXPERIMENTS.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalMode {
    /// Coefficients and MAC in f64 (pure approximation error).
    Exact,
    /// Coefficients and MAC in f32 (what the Pallas kernel does).
    F32,
    /// Coefficients quantized to fp16, MAC computed then rounded to fp16,
    /// output flushed-to-zero on subnormal (the strictest hardware view).
    F16,
    /// Like [`EvalMode::F16`] but subnormal *outputs* are kept (only
    /// subnormal inputs are excluded, as the paper states).  This is the
    /// mode that reproduces the paper's flat ~2.7e-2 MRE curve.
    F16Round,
}

impl PwlExp2 {
    /// Build the coefficient tables.  Segment `k` covers
    /// `xf ∈ [-(k+1)/S, -k/S)` with the right-closed end at `xf = 0`
    /// folded into `k = 0`.
    pub fn new(segments: usize) -> PwlExp2 {
        assert!(segments >= 1, "segments must be >= 1");
        let s = segments as f64;
        let mut slopes = Vec::with_capacity(segments);
        let mut intercepts = Vec::with_capacity(segments);
        for k in 0..segments {
            let b = -(k as f64) / s;
            let a = -((k + 1) as f64) / s;
            let slope = (b.exp2() - a.exp2()) / (b - a);
            let intercept = a.exp2() - slope * a;
            slopes.push(slope);
            intercepts.push(intercept);
        }
        PwlExp2 { segments, slopes, intercepts }
    }

    /// Segment index for a fraction `xf ∈ (-1, 0]`.
    #[inline]
    pub fn segment(&self, xf: f64) -> usize {
        let k = (-xf * self.segments as f64).floor() as isize;
        k.clamp(0, self.segments as isize - 1) as usize
    }

    /// Split `x <= 0` into `(xi, xf)` with `xf ∈ (-1, 0]` — the Split unit.
    #[inline]
    pub fn split(x: f64) -> (f64, f64) {
        let xi = x.ceil();
        (xi, x - xi)
    }

    /// exp2(x) for x <= 0 in f64 (approximation error only).
    pub fn eval(&self, x: f64) -> f64 {
        let (xi, xf) = Self::split(x);
        let k = self.segment(xf);
        let frac = self.slopes[k] * xf + self.intercepts[k];
        // 2^xi as an exponent shift; exp2 of a float integer is exact.
        let xi = xi.clamp(-1074.0, 1023.0);
        xi.exp2() * frac
    }

    /// exp2(x) in f32 — bit-matches the Pallas kernel's in-kernel PWL.
    pub fn eval_f32(&self, x: f32) -> f32 {
        let xi = x.ceil();
        let xf = x - xi;
        let k = self.segment(xf as f64);
        let frac = self.slopes[k] as f32 * xf + self.intercepts[k] as f32;
        let xi = xi.clamp(-126.0, 127.0);
        xi.exp2() * frac
    }

    /// Bit-level fp16 hardware evaluation: fp16 input, fp16 coefficients,
    /// MAC result rounded to fp16, exponent shift by xi; optional
    /// subnormal flush on the output.
    pub fn eval_f16_mode(&self, x: F16, flush: bool) -> F16 {
        let xv = x.to_f32();
        let xi = xv.ceil();
        let xf = F16::from_f32(xv - xi).to_f32();
        let k = self.segment(xf as f64);
        let slope = F16::from_f32(self.slopes[k] as f32).to_f32();
        let intercept = F16::from_f32(self.intercepts[k] as f32).to_f32();
        let frac = F16::from_f32(slope * xf + intercept).to_f32();
        let shifted = frac * (xi.clamp(-30.0, 30.0)).exp2();
        let out = F16::from_f32(shifted);
        if flush {
            out.flush_subnormal()
        } else {
            out
        }
    }

    /// [`Self::eval_f16_mode`] with flush-to-zero (back-compat helper).
    pub fn eval_f16(&self, x: F16) -> F16 {
        self.eval_f16_mode(x, true)
    }

    /// f32-in/f32-out evaluation with the *interpolation MAC performed in
    /// fp16* — the PE datapath of the FSA silicon (fp16 multipliers,
    /// coefficients streamed as fp16).  The exponent shift by `xi` is
    /// exact.  This is the evaluator the cycle simulator and the Pallas
    /// kernel use in fp16 mode; its ~2.7e-2 relative error is what drives
    /// the paper's Table-2 magnitudes.
    pub fn eval_f16_mac(&self, x: f32) -> f32 {
        let xi = x.ceil();
        let xf = F16::from_f32(x - xi).to_f32();
        let k = self.segment(xf as f64);
        let slope = F16::from_f32(self.slopes[k] as f32).to_f32();
        let intercept = F16::from_f32(self.intercepts[k] as f32).to_f32();
        let frac = F16::from_f32(slope * xf + intercept).to_f32();
        frac * xi.clamp(-126.0, 127.0).exp2()
    }

    /// The §3.3 trick: every intercept lies in (0.5, 1], so its exponent
    /// field is 0 (value 1.0) or -1 (everything else) and the high mantissa
    /// bits suffice to recover `k` without extra control wires.  Returns
    /// the index encoded for segment `k` and checks invertibility.
    pub fn intercept_exponent_encoding(&self) -> Vec<(usize, u16)> {
        self.intercepts
            .iter()
            .enumerate()
            .map(|(k, &c)| {
                let h = F16::from_f32(c as f32);
                (k, h.to_bits())
            })
            .collect()
    }
}

/// Error statistics of a PWL approximation over all negative normal fp16
/// values — the exact sweep of paper Fig. 12.
#[derive(Clone, Copy, Debug, Default)]
pub struct ErrorStats {
    pub mae: f64,
    pub mre: f64,
    pub max_abs: f64,
    pub max_rel: f64,
    pub count: usize,
}

/// Exhaustive Fig.-12 sweep: mean absolute / mean relative error of the
/// S-segment PWL over all negative normal fp16 inputs, vs an exact f64
/// exp2 reference.
pub fn error_sweep(segments: usize, mode: EvalMode) -> ErrorStats {
    error_sweep_ref(segments, mode, false)
}

/// Like [`error_sweep`], but optionally round the *reference* to fp16
/// first (`ref_f16 = true`), i.e. measure against the best any fp16
/// producer could do.  The paper does not state its reference precision;
/// this reproduces the flat ~2.7e-2 MRE of Fig. 12 (see EXPERIMENTS.md).
pub fn error_sweep_ref(segments: usize, mode: EvalMode, ref_f16: bool) -> ErrorStats {
    let pwl = PwlExp2::new(segments);
    let mut stats = ErrorStats::default();
    let mut abs_sum = 0.0f64;
    let mut rel_sum = 0.0f64;
    let mut n = 0usize;
    for h in negative_normals() {
        let x = h.to_f64();
        let exact = if ref_f16 {
            F16::from_f32(x.exp2() as f32).to_f64()
        } else {
            x.exp2()
        };
        let approx = match mode {
            EvalMode::Exact => pwl.eval(x),
            EvalMode::F32 => pwl.eval_f32(x as f32) as f64,
            EvalMode::F16 => pwl.eval_f16_mode(h, true).to_f64(),
            EvalMode::F16Round => pwl.eval_f16_mode(h, false).to_f64(),
        };
        let abs = (approx - exact).abs();
        let rel = if exact != 0.0 { abs / exact } else { 0.0 };
        abs_sum += abs;
        rel_sum += rel;
        stats.max_abs = stats.max_abs.max(abs);
        stats.max_rel = stats.max_rel.max(rel);
        n += 1;
    }
    stats.mae = abs_sum / n as f64;
    stats.mre = rel_sum / n as f64;
    stats.count = n;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_at_breakpoints() {
        for s in [1usize, 2, 4, 8, 16, 32, 64] {
            let pwl = PwlExp2::new(s);
            for k in 0..s {
                for x in [-(k as f64) / s as f64, -((k + 1) as f64) / s as f64] {
                    let approx = pwl.slopes[k] * x + pwl.intercepts[k];
                    assert!(
                        (approx - x.exp2()).abs() < 1e-12,
                        "s={s} k={k} x={x}"
                    );
                }
            }
        }
    }

    #[test]
    fn intercepts_in_half_open_unit_range() {
        // Paper §3.3: intercepts ∈ (0.5, 1] -> exponent is 0 or -1.
        for s in [2usize, 4, 8, 16, 32] {
            let pwl = PwlExp2::new(s);
            for &c in &pwl.intercepts {
                assert!(c > 0.5 && c <= 1.0, "s={s} c={c}");
            }
            // The fp16 encoding of each intercept must be distinct so the
            // mantissa MSBs can address the segment (§3.3's control trick).
            let enc = pwl.intercept_exponent_encoding();
            let mut bits: Vec<u16> = enc.iter().map(|&(_, b)| b).collect();
            bits.sort_unstable();
            bits.dedup();
            assert_eq!(bits.len(), s, "fp16-encoded intercepts collide");
        }
    }

    #[test]
    fn split_matches_paper_ranges() {
        for x in [-0.0, -0.25, -1.0, -1.75, -7.001, -30.999] {
            let (xi, xf) = PwlExp2::split(x);
            assert_eq!(xi, x.ceil());
            assert!(xf > -1.0 && xf <= 0.0, "x={x} xf={xf}");
            assert!((xi + xf - x).abs() < 1e-12);
        }
    }

    #[test]
    fn eval_exact_at_integers() {
        let pwl = PwlExp2::new(8);
        for i in 0..30 {
            let x = -(i as f64);
            assert!((pwl.eval(x) - x.exp2()).abs() < 1e-12 * x.exp2().max(1e-300));
        }
    }

    #[test]
    fn error_decreases_with_segments() {
        let maes: Vec<f64> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&s| error_sweep(s, EvalMode::Exact).mae)
            .collect();
        for w in maes.windows(2) {
            assert!(w[0] > w[1], "MAE not decreasing: {maes:?}");
        }
    }

    #[test]
    fn eight_segments_match_paper_mae_order() {
        // Paper: 8 segments -> MAE 0.00014.  Pure approximation error lands
        // in the same decade; the exact figure depends on quantization mode
        // (see EXPERIMENTS.md discussion).
        let st = error_sweep(8, EvalMode::Exact);
        assert!(st.mae < 5e-4, "MAE {}", st.mae);
        assert!(st.mae > 5e-6, "MAE {}", st.mae);
        // Max relative error of the pure PWL is bounded by interpolation
        // theory: (ln 2)^2 / (8 * 64) / 2 < 2e-3 on (-1, 0].
        assert!(st.max_rel < 2e-3, "max rel {}", st.max_rel);
    }

    #[test]
    fn f32_mode_matches_exact_closely() {
        let pwl = PwlExp2::new(8);
        for i in 0..1000 {
            let x = -(i as f64) * 0.02;
            let a = pwl.eval(x);
            let b = pwl.eval_f32(x as f32) as f64;
            assert!((a - b).abs() <= 1e-6 * a.abs().max(1e-30), "x={x}");
        }
    }

    #[test]
    fn segment_lookup_boundaries() {
        let pwl = PwlExp2::new(8);
        assert_eq!(pwl.segment(0.0), 0);
        assert_eq!(pwl.segment(-0.124), 0);
        assert_eq!(pwl.segment(-0.125), 1);
        assert_eq!(pwl.segment(-0.999), 7);
    }
}
