//! Software IEEE 754 binary16 ("half", fp16).
//!
//! The offline environment has no `half` crate, and the paper's error
//! analysis (§6.2.1, Fig. 12) needs exact control over rounding and
//! subnormal handling anyway: FSA evaluates the PWL approximation over
//! *all negative normal fp16 values* and flushes subnormals to zero "as
//! most accelerators do".  This module provides bit-exact conversions with
//! round-to-nearest-even, classification helpers, and the exhaustive
//! enumerations the sweeps are built on.

/// A binary16 value stored as its raw bit pattern.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct F16(pub u16);

const EXP_BITS: u32 = 5;
const MAN_BITS: u32 = 10;
const EXP_BIAS: i32 = 15;

impl F16 {
    pub const ZERO: F16 = F16(0);
    pub const NEG_ZERO: F16 = F16(0x8000);
    pub const ONE: F16 = F16(0x3C00);
    pub const INFINITY: F16 = F16(0x7C00);
    pub const NEG_INFINITY: F16 = F16(0xFC00);
    pub const NAN: F16 = F16(0x7E00);
    /// Largest finite magnitude (65504).
    pub const MAX: F16 = F16(0x7BFF);
    /// Smallest positive normal (2^-14).
    pub const MIN_POSITIVE_NORMAL: F16 = F16(0x0400);

    #[inline]
    pub fn from_bits(bits: u16) -> F16 {
        F16(bits)
    }

    #[inline]
    pub fn to_bits(self) -> u16 {
        self.0
    }

    /// Convert from f32 with round-to-nearest-even (the IEEE default used
    /// by MXU-style multipliers when quantizing activations).
    pub fn from_f32(x: f32) -> F16 {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp = ((bits >> 23) & 0xFF) as i32;
        let man = bits & 0x007F_FFFF;

        if exp == 0xFF {
            // Inf / NaN. Preserve a quiet NaN payload bit.
            return if man == 0 {
                F16(sign | 0x7C00)
            } else {
                F16(sign | 0x7E00)
            };
        }

        // Unbiased exponent in f32; rebias for f16.
        let unbiased = exp - 127;
        let e16 = unbiased + EXP_BIAS;

        if e16 >= 0x1F {
            // Overflow -> infinity.
            return F16(sign | 0x7C00);
        }
        if e16 <= 0 {
            // Subnormal or underflow-to-zero in f16.
            if e16 < -10 {
                return F16(sign); // rounds to +-0
            }
            // Implicit leading 1 becomes explicit; shift right by (1 - e16).
            let man = man | 0x0080_0000;
            let shift = (14 - e16) as u32; // 23 - 10 + (1 - e16)
            let half = 1u32 << (shift - 1);
            let rest_mask = half - 1;
            let mut out = (man >> shift) as u16;
            let rem = man & (half | rest_mask);
            if rem > half || (rem == half && out & 1 == 1) {
                out += 1; // RNE; may carry into the normal range, which is fine
            }
            return F16(sign | out);
        }

        // Normal range: round mantissa 23 -> 10 bits, RNE.
        let shift = 13u32;
        let half = 1u32 << (shift - 1);
        let rest_mask = half - 1;
        let mut out = ((e16 as u32) << MAN_BITS) as u16 | (man >> shift) as u16;
        let rem = man & (half | rest_mask);
        if rem > half || (rem == half && out & 1 == 1) {
            out += 1; // mantissa carry correctly increments the exponent
        }
        F16(sign | out)
    }

    /// Exact widening conversion to f32.
    pub fn to_f32(self) -> f32 {
        let sign = ((self.0 & 0x8000) as u32) << 16;
        let exp = ((self.0 >> MAN_BITS) & 0x1F) as u32;
        let man = (self.0 & 0x03FF) as u32;
        let bits = if exp == 0 {
            if man == 0 {
                sign // +-0
            } else {
                // Subnormal: value = man * 2^-24 with highest set bit h;
                // normalized f32 exponent is h - 24 (biased: 134 - clz).
                let lz = man.leading_zeros() - 21; // zeros above bit 10
                let man = (man << lz) & 0x03FF; // implicit bit drops off
                let exp = (127 - EXP_BIAS + 1 - lz as i32) as u32;
                sign | (exp << 23) | (man << 13)
            }
        } else if exp == 0x1F {
            sign | 0x7F80_0000 | (man << 13) // inf / nan
        } else {
            sign | ((exp + 127 - EXP_BIAS as u32) << 23) | (man << 13)
        };
        f32::from_bits(bits)
    }

    pub fn to_f64(self) -> f64 {
        self.to_f32() as f64
    }

    pub fn is_nan(self) -> bool {
        (self.0 & 0x7C00) == 0x7C00 && (self.0 & 0x03FF) != 0
    }

    pub fn is_infinite(self) -> bool {
        (self.0 & 0x7FFF) == 0x7C00
    }

    pub fn is_subnormal(self) -> bool {
        (self.0 & 0x7C00) == 0 && (self.0 & 0x03FF) != 0
    }

    pub fn is_normal(self) -> bool {
        let e = self.0 & 0x7C00;
        e != 0 && e != 0x7C00
    }

    pub fn is_sign_negative(self) -> bool {
        self.0 & 0x8000 != 0
    }

    /// Flush subnormals to (sign-preserving) zero — accelerator semantics
    /// assumed throughout the paper (§6.2.1, citing bfloat16 docs).
    pub fn flush_subnormal(self) -> F16 {
        if self.is_subnormal() {
            F16(self.0 & 0x8000)
        } else {
            self
        }
    }
}

/// Round-trip an f32 through fp16 (RNE) — the quantization a value suffers
/// when written to an FSA activation register.
#[inline]
pub fn quantize_f32(x: f32) -> f32 {
    F16::from_f32(x).to_f32()
}

/// fp16 quantization with flush-to-zero on subnormals — the accelerator
/// semantics the paper assumes (§6.2.1).  This is what makes Table 2's
/// error grow with sequence length: softmax weights scale like 1/L, and
/// at L = 16 K the typical weight (6e-5) sits at the fp16 subnormal
/// boundary, so flushed weights vanish from the PV accumulation.
#[inline]
pub fn quantize_ftz_f32(x: f32) -> f32 {
    F16::from_f32(x).flush_subnormal().to_f32()
}

/// All negative *normal* fp16 values in increasing-magnitude order
/// (exp 1..=30, mantissa 0..=1023: 30 * 1024 = 30720 values).  The domain
/// of the paper's exhaustive Fig. 12 sweep.
pub fn negative_normals() -> impl Iterator<Item = F16> {
    (1u16..=30).flat_map(|e| (0u16..1024).map(move |m| F16(0x8000 | (e << 10) | m)))
}

/// Every finite fp16 value (both signs, subnormals included) — used by
/// round-trip property tests.
pub fn all_finite() -> impl Iterator<Item = F16> {
    (0u16..=0xFFFF).map(F16).filter(|h| !h.is_nan() && !h.is_infinite())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_convert_exactly() {
        assert_eq!(F16::ONE.to_f32(), 1.0);
        assert_eq!(F16::ZERO.to_f32(), 0.0);
        assert_eq!(F16::MAX.to_f32(), 65504.0);
        assert_eq!(F16::MIN_POSITIVE_NORMAL.to_f32(), 2.0f32.powi(-14));
        assert!(F16::NAN.is_nan());
        assert!(F16::INFINITY.is_infinite());
        assert_eq!(F16::NEG_INFINITY.to_f32(), f32::NEG_INFINITY);
    }

    #[test]
    fn roundtrip_all_finite_values() {
        // to_f32 is exact, so from_f32(to_f32(h)) must return h bit-exactly
        // (modulo nothing: every finite f16 is representable in f32).
        for h in all_finite() {
            let back = F16::from_f32(h.to_f32());
            assert_eq!(back.0, h.0, "bits {:#06x}", h.0);
        }
    }

    #[test]
    fn rne_ties_to_even() {
        // 1.0 + 2^-11 is exactly halfway between 1.0 and the next f16; RNE
        // keeps the even mantissa (1.0).
        let x = 1.0f32 + 2.0f32.powi(-11);
        assert_eq!(F16::from_f32(x), F16::ONE);
        // 1.0 + 3*2^-11 is halfway between odd and even; rounds up to even.
        let y = 1.0f32 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(F16::from_f32(y).0, F16(0x3C02).0);
    }

    #[test]
    fn overflow_and_underflow() {
        assert!(F16::from_f32(1e6).is_infinite());
        assert!(F16::from_f32(-1e6).is_infinite());
        assert_eq!(F16::from_f32(1e-12).0, 0);
        assert_eq!(F16::from_f32(-1e-12).0, 0x8000);
        // Largest f32 that still rounds to MAX rather than inf.
        assert_eq!(F16::from_f32(65519.0), F16::MAX);
        assert!(F16::from_f32(65520.0).is_infinite());
    }

    #[test]
    fn subnormal_handling() {
        let tiny = 2.0f32.powi(-24); // smallest positive f16 subnormal
        let h = F16::from_f32(tiny);
        assert!(h.is_subnormal());
        assert_eq!(h.to_f32(), tiny);
        assert_eq!(h.flush_subnormal(), F16::ZERO);
        let neg = F16::from_f32(-tiny);
        assert_eq!(neg.flush_subnormal(), F16::NEG_ZERO);
    }

    #[test]
    fn negative_normals_enumeration() {
        let v: Vec<F16> = negative_normals().collect();
        assert_eq!(v.len(), 30 * 1024);
        assert!(v.iter().all(|h| h.is_normal() && h.is_sign_negative()));
        assert_eq!(v[0].to_f32(), -(2.0f32.powi(-14)));
        assert_eq!(v[v.len() - 1].to_f32(), -65504.0);
    }

    #[test]
    fn matches_reference_conversion_on_grid() {
        // Cross-check from_f32 against a simple nearest-search oracle on a
        // coarse grid of interesting values.
        for i in -60..60 {
            for frac in [1.0f32, 1.1, 1.5, 1.999, 1.0009765625] {
                let x = frac * 2.0f32.powi(i);
                let h = F16::from_f32(x);
                if h.is_infinite() || x.abs() < 2.0f32.powi(-26) {
                    continue;
                }
                let err = (h.to_f32() - x).abs();
                // Nearest f16 is within half a ulp of x.
                let ulp = if x.abs() >= 2.0f32.powi(-14) {
                    2.0f32.powi(i - 10).abs().max(2.0f32.powi(-24))
                } else {
                    2.0f32.powi(-24)
                };
                assert!(err <= ulp, "x={x} h={} err={err} ulp={ulp}", h.to_f32());
            }
        }
    }
}
