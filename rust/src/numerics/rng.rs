//! Deterministic PRNG + samplers (no `rand` crate in the offline env).
//!
//! SplitMix64 is small, fast, and passes BigCrush-level smoke statistics —
//! plenty for workload generation.  The normal sampler is Box–Muller; the
//! spiky mixture reproduces the paper's §6.2.2 input distribution
//! `N(0,1) + N(0,100) * Bernoulli(0.001)` (FlashAttention-3's accuracy
//! evaluation setup).

/// SplitMix64 PRNG (public-domain algorithm by Sebastiano Vigna).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> SplitMix64 {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        // Multiply-shift; bias is < 2^-64 * n, irrelevant here.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Paper §6.2.2: `N(0,1) + N(0,100) * Bernoulli(0.001)`.
    pub fn next_spiky(&mut self) -> f64 {
        let base = self.next_normal();
        if self.next_f64() < 0.001 {
            base + 10.0 * self.next_normal() // std 10 => variance 100
        } else {
            base
        }
    }

    /// Fill a row-major matrix with standard normals (f32).
    pub fn normal_matrix(&mut self, rows: usize, cols: usize) -> Vec<f32> {
        (0..rows * cols).map(|_| self.next_normal() as f32).collect()
    }

    /// Fill a row-major matrix with the spiky attention-input distribution.
    pub fn spiky_matrix(&mut self, rows: usize, cols: usize) -> Vec<f32> {
        (0..rows * cols).map(|_| self.next_spiky() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = SplitMix64::new(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitMix64::new(7);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn spiky_distribution_tail() {
        let mut r = SplitMix64::new(9);
        let n = 400_000;
        let spikes = (0..n).filter(|_| r.next_spiky().abs() > 6.0).count();
        // P(|N(0,1)| > 6) ~ 2e-9; nearly all 6-sigma events come from the
        // 0.1% mixture, whose |value| > 6 probability is ~0.55.
        let rate = spikes as f64 / n as f64;
        assert!(rate > 2e-4 && rate < 1.2e-3, "rate={rate}");
    }

    #[test]
    fn next_below_bounds() {
        let mut r = SplitMix64::new(3);
        for _ in 0..10_000 {
            assert!(r.next_below(17) < 17);
        }
    }
}
