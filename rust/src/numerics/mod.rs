//! Numerics substrate: software binary16, the PWL exp2 contract of the FSA
//! Split unit, and the paper's input distributions.
//!
//! Everything here is deterministic and dependency-free so that the cycle
//! simulator, the performance models and the error-analysis benches (paper
//! Fig. 12, Table 2) share one bit-careful implementation.

pub mod f16;
pub mod pwl;
pub mod reference;
pub mod rng;

pub use f16::F16;
pub use pwl::PwlExp2;
pub use rng::SplitMix64;

/// log2(e), the constant FSA streams through the array for the
/// `exp(x) = exp2(log2(e) * x)` rewrite (Algorithm 1, line 10/12).
pub const LOG2E: f64 = std::f64::consts::LOG2_E;
