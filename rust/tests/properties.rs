//! Property-based tests over the crate's invariants (the proptest role;
//! harness in `fsa::testutil`).

use fsa::isa::encode::{decode_program, encode_program};
use fsa::isa::{Instruction, LaneBound, Program, Space, TileDesc};
use fsa::numerics::f16::{quantize_f32, quantize_ftz_f32, F16};
use fsa::numerics::pwl::PwlExp2;
use fsa::numerics::reference::{flash_forward, mat_error, sdpa, Exp2, Mat, Precision};
use fsa::schedule::{InnerSchedule, Variant};
use fsa::testutil::Prop;

#[test]
fn prop_f16_roundtrip_is_idempotent_and_monotone() {
    Prop::new("f16_roundtrip").cases(500).run(|g| {
        let x = (g.f32_normal()) * 10f32.powi(g.i64_in(-8, 4) as i32);
        let q1 = quantize_f32(x);
        assert_eq!(quantize_f32(q1), q1, "idempotent");
        assert!((q1 - x).abs() <= x.abs() * 0.001 + 1e-7 || q1.is_infinite());
        // FTZ only ever moves a value to zero.
        let q2 = quantize_ftz_f32(x);
        assert!(q2 == q1 || q2 == 0.0 || q2 == -0.0);
        // Ordering preserved for two values a cell apart.
        let y = x * 1.5 + 0.25;
        if x < y {
            assert!(quantize_f32(x) <= quantize_f32(y));
        }
    });
}

#[test]
fn prop_pwl_error_bound_and_positivity() {
    Prop::new("pwl_bounds").cases(300).run(|g| {
        let segments = *g.choose(&[1usize, 2, 4, 8, 16, 32]);
        let pwl = PwlExp2::new(segments);
        let x = -g.f64_in(0.0, 40.0);
        let approx = pwl.eval(x);
        let exact = x.exp2();
        assert!(approx > 0.0 || exact < 1e-300, "positive on (-inf,0]");
        // Interp theory: |err| = 2^xi * |interp err on xf| with
        // |interp err| <= (ln2/S)^2 / 8 * max 2^xf = (ln2/S)^2 / 8.
        let xi = x.ceil().max(-1074.0);
        let bound = (2f64.ln() / segments as f64).powi(2) / 8.0 * xi.exp2() + 1e-300;
        assert!(
            (approx - exact).abs() <= bound * 1.0001,
            "x={x} approx={approx} exact={exact} bound={bound}"
        );
    });
}

#[test]
fn prop_isa_roundtrip_fuzz() {
    Prop::new("isa_roundtrip").cases(500).run(|g| {
        let tile = |g: &mut fsa::testutil::Gen, space| TileDesc {
            space,
            addr: g.usize_in(0, (1 << 24) - 1) as u32,
            rows: 1u16 << g.usize_in(0, 10),
            cols: 1u16 << g.usize_in(0, 10),
            stride: g.usize_in(1, 0xF_FFFF) as u32,
        };
        let a = tile(g, Space::Spad);
        let b = tile(g, Space::Accum);
        let m = tile(g, Space::Main);
        let first = g.bool();
        let insn = match g.usize_in(0, 7) {
            0 => Instruction::LoadTile { src: m, dst: a },
            1 => Instruction::StoreTile { src: b, dst: m },
            2 => Instruction::LoadStationary { src: a },
            3 => Instruction::AttnScore { k: a, lse: b, first, masked: g.bool() },
            4 => Instruction::AttnValue { v: a, out: b, first },
            5 => Instruction::Reciprocal { l: b },
            6 => Instruction::MaskBound {
                bound: LaneBound {
                    base: g.usize_in(0, 1 << 20) as i32 - (1 << 19),
                    diag: g.bool(),
                    cap: g.usize_in(0, 1024) as u16,
                },
            },
            _ => Instruction::AttnLseNorm { out: b, l: b },
        };
        let mut p = Program::new();
        p.push(insn);
        let words = encode_program(&p).expect("encodable");
        assert_eq!(decode_program(&words).expect("decodable"), p);
    });
}

#[test]
fn prop_flash_matches_dense_for_random_shapes() {
    Prop::new("flash_vs_dense").cases(40).run(|g| {
        let br = *g.choose(&[4usize, 8, 16]);
        let bc = *g.choose(&[4usize, 8, 16]);
        let tr = g.usize_in(1, 3);
        let tc = g.usize_in(1, 3);
        let d = *g.choose(&[4usize, 8, 16]);
        let (l, lk) = (tr * br, tc * bc);
        let q = Mat::new(l, d, g.matrix(l, d));
        let k = Mat::new(lk, d, g.matrix(lk, d));
        let v = Mat::new(lk, d, g.matrix(lk, d));
        let exact = flash_forward(&q, &k, &v, br, bc, &Exp2::Exact, Precision::F32);
        let dense = sdpa(&q, &k, &v);
        let err = mat_error(&exact, &dense);
        assert!(err.max_abs < 1e-4, "exact flash drifted: {err:?}");
        // The fp16/PWL device path stays within the paper's error band.
        let device = fsa::numerics::reference::flash_pwl(&q, &k, &v, br, bc, 8);
        let derr = mat_error(&device, &dense);
        assert!(derr.mae < 3e-2, "device numerics out of band: {derr:?}");
        assert!(device.data.iter().all(|x| x.is_finite()));
    });
}

#[test]
fn prop_schedule_waves_never_collide() {
    // For every (n, m) pair and every pair of distinct waves, application
    // cycles at the same PE must differ (no two writes to one register in
    // one cycle) — the analytical form of the array's hazard check.
    Prop::new("wave_disjoint").cases(60).run(|g| {
        let n = *g.choose(&[4usize, 8, 16, 32]);
        let s = InnerSchedule::new(n, Variant::DualPath, 8);
        let row = g.usize_in(0, n - 1);
        let col = g.usize_in(0, n - 1);
        let mut cycles: Vec<u64> = (0..10).map(|w| s.elementwise(w, row, col)).collect();
        cycles.push(s.rowsum_at(row, col));
        cycles.push(s.s_parked(col, row));
        for h in 0..n {
            cycles.push(s.pv_at(row, col, h));
        }
        let len = cycles.len();
        cycles.sort_unstable();
        cycles.dedup();
        assert_eq!(cycles.len(), len, "wave collision at PE({row},{col}) n={n}");
    });
}

#[test]
fn prop_seq_bucket_minimal_cover() {
    Prop::new("bucket_cover").cases(200).run(|g| {
        let mut buckets: Vec<usize> = (0..g.usize_in(1, 6)).map(|_| g.usize_in(1, 4096)).collect();
        buckets.sort_unstable();
        buckets.dedup();
        let want = g.usize_in(1, 5000);
        match fsa::coordinator::seq_bucket(want, &buckets) {
            Some(b) => {
                assert!(b >= want);
                assert!(buckets.iter().all(|&x| x < want || x >= b), "not minimal");
            }
            None => assert!(buckets.iter().all(|&x| x < want)),
        }
    });
}

#[test]
fn prop_negative_normals_cover_exactly_the_domain() {
    // Exhaustive double-check of the Fig-12 sweep domain.
    let mut count = 0usize;
    for h in fsa::numerics::f16::negative_normals() {
        assert!(h.is_normal() && h.is_sign_negative());
        count += 1;
    }
    assert_eq!(count, 30 * 1024);
    // And no finite f16 is both normal-negative and missed: count matches
    // the closed form 30 exponents x 1024 mantissas.
    let total_neg_normal = fsa::numerics::f16::all_finite()
        .filter(|h| h.is_normal() && h.is_sign_negative())
        .count();
    assert_eq!(total_neg_normal, count);
    let _ = F16::ONE;
}
