//! E2e contract of the telemetry layer (DESIGN.md §9):
//!
//! * enabling tracing changes **no served bits** — identical workloads
//!   on trace-off and trace-full pools produce bitwise-equal outputs,
//!   on both the reference and the cycle-accurate sim backends;
//! * the full-trace event stream covers the whole request path
//!   (admit → shard → dispatch → execute → gather, plus KV traffic)
//!   with counts that reconcile against the serving metrics, and sim
//!   Execute payloads sum exactly to the shard-cycle counter;
//! * `Metrics::snapshot` serializes through the dependency-free JSON
//!   writer and parses back with the schema `fsa serve --metrics-json`
//!   and `BENCH_serving.json` share.

use fsa::config::{BackendKind, RunConfig};
use fsa::coordinator::request::AttentionRequest;
use fsa::coordinator::trace::{EventKind, TraceLevel};
use fsa::coordinator::Coordinator;
use fsa::mask::MaskKind;
use fsa::numerics::SplitMix64;

const N: usize = 32;

fn cfg(backend: BackendKind, trace: TraceLevel, devices: usize) -> RunConfig {
    RunConfig {
        devices,
        max_batch: 8,
        batch_timeout_cycles: 50_000,
        queue_depth: 64,
        backend,
        num_heads: 4,
        num_kv_heads: 2,
        sim_max_seq: 256,
        array_size: N,
        trace,
        ..RunConfig::default()
    }
}

fn gqa_req(seed: u64, id: u64, seq: usize, d: usize, heads: usize, kv: usize) -> AttentionRequest {
    let mut rng = SplitMix64::new(seed);
    AttentionRequest::gqa(
        id,
        seq,
        d,
        heads,
        kv,
        rng.normal_matrix(heads * seq, d),
        rng.normal_matrix(kv * seq, d),
        rng.normal_matrix(kv * seq, d),
    )
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The mixed workload both pools serve: 3 stateless causal GQA requests
/// plus one session (causal prefill, 2 decode steps, close).  Returns
/// every output in submission order.
fn run_workload(coord: &Coordinator, seq: usize, d: usize) -> Vec<Vec<f32>> {
    let (heads, kv) = (4usize, 2usize);
    let mut outs = Vec::new();
    for i in 0..3u64 {
        let req = gqa_req(100 + i, i, seq, d, heads, kv).with_mask(MaskKind::Causal);
        outs.push(coord.submit_wait(req).unwrap().output.expect("stateless serving"));
    }
    let mut rng = SplitMix64::new(777);
    let prefill = AttentionRequest::prefill(
        10,
        5,
        seq,
        d,
        heads,
        kv,
        rng.normal_matrix(heads * seq, d),
        rng.normal_matrix(kv * seq, d),
        rng.normal_matrix(kv * seq, d),
    )
    .with_mask(MaskKind::Causal);
    outs.push(coord.submit_wait(prefill).unwrap().output.expect("prefill"));
    for step in 0..2u64 {
        let dec = AttentionRequest::decode(
            20 + step,
            5,
            step,
            d,
            heads,
            kv,
            rng.normal_matrix(heads, d),
            rng.normal_matrix(kv, d),
            rng.normal_matrix(kv, d),
        );
        outs.push(coord.submit_wait(dec).unwrap().output.expect("decode step"));
    }
    coord.submit_wait(AttentionRequest::close(99, 5)).unwrap();
    outs
}

/// Acceptance: full tracing on the reference pool changes no served
/// bits, and the recorded spans cover the whole request path with
/// counts that reconcile against the serving metrics.
#[test]
fn tracing_changes_no_served_bits_on_the_reference_pool() {
    let (seq, d) = (32usize, 16usize);
    let off = Coordinator::start(cfg(BackendKind::Reference, TraceLevel::Off, 2)).unwrap();
    let full = Coordinator::start(cfg(BackendKind::Reference, TraceLevel::Full, 2)).unwrap();

    let want = run_workload(&off, seq, d);
    let got = run_workload(&full, seq, d);
    assert_eq!(want.len(), got.len());
    for (i, (w, g)) in want.iter().zip(&got).enumerate() {
        assert_eq!(bits(w), bits(g), "stage {i}: tracing changed served bits");
    }

    // The off pool recorded literally nothing.
    assert!(!off.tracer.enabled());
    for kind in EventKind::ALL {
        assert_eq!(off.tracer.count(kind), 0, "{}", kind.name());
    }
    assert!(off.tracer.events().is_empty());

    // The full pool's counts reconcile with the metrics: 6 dispatched
    // requests (3 stateless + prefill + 2 decode; close is answered
    // inline and never admitted to the shard path), one Shard and one
    // Gather each, and one Dispatch + Execute per head shard.
    let o = std::sync::atomic::Ordering::Relaxed;
    let t = &full.tracer;
    assert_eq!(t.count(EventKind::Admit), 6);
    assert_eq!(t.count(EventKind::Shard), 6);
    assert_eq!(t.count(EventKind::Gather), 6);
    let shards = full.metrics.head_shards.load(o) as u64;
    assert!(shards > 0);
    assert_eq!(t.count(EventKind::Dispatch), shards);
    assert_eq!(t.count(EventKind::Execute), shards);
    assert_eq!(t.count(EventKind::KvHit), full.metrics.kv_hits.load(o));
    assert_eq!(t.count(EventKind::KvMiss), full.metrics.kv_misses.load(o));
    assert!(t.count(EventKind::KvHit) + t.count(EventKind::KvMiss) > 0, "decode touched KV");

    // Retained events exist (Full level), and Admit events carry the
    // sequence length as payload.  (Strict timestamp ordering is a
    // single-thread property — asserted in the trace unit tests, not
    // here where two device workers interleave.)
    let evs = t.events();
    assert!(!evs.is_empty());
    assert!(
        evs.iter().any(|e| e.kind == EventKind::Admit && e.payload == seq as u64),
        "an Admit event must carry seq_len"
    );
    let s = t.summary();
    assert!(s.contains("admit=6") && s.contains("execute="), "{s}");

    off.shutdown();
    full.shutdown();
}

/// Acceptance: the same bitwise contract on the cycle-accurate sim
/// pool — plus the exact-sum attribution bridges: traced Execute
/// payloads sum to the shard-cycle counter, and the per-response
/// breakdowns are identical across trace levels (tracing must not move
/// a single simulated cycle).
#[test]
fn tracing_changes_no_served_bits_on_the_sim_pool() {
    let (seq, d, heads, kv) = (48usize, 16usize, 2usize, 1usize);
    let off = Coordinator::start(cfg(BackendKind::Sim, TraceLevel::Off, 2)).unwrap();
    let full = Coordinator::start(cfg(BackendKind::Sim, TraceLevel::Full, 2)).unwrap();

    for (i, mask) in [MaskKind::None, MaskKind::Causal].into_iter().enumerate() {
        let req = gqa_req(5000 + i as u64, 1 + i as u64, seq, d, heads, kv).with_mask(mask);
        let want = off.submit_wait(req.clone()).unwrap();
        let got = full.submit_wait(req).unwrap();
        assert_eq!(
            bits(&want.output.expect("untraced sim serving")),
            bits(&got.output.expect("traced sim serving")),
            "{mask:?}: tracing changed served bits"
        );
        assert_eq!(got.device_cycles, want.device_cycles, "{mask:?}");
        assert_eq!(got.stats.cycle_breakdown, want.stats.cycle_breakdown, "{mask:?}");
        let bd = got.stats.cycle_breakdown.expect("sim responses carry attribution");
        assert_eq!(bd.total(), got.device_cycles, "{mask:?}: {bd:?}");
    }

    // Every Execute event carries its shard's measured cycles; the ring
    // held them all (few shards << RING_CAP), so the payloads sum
    // exactly to the worker-side cycle counter.
    let o = std::sync::atomic::Ordering::Relaxed;
    assert_eq!(full.tracer.overwritten(), 0);
    let traced: u64 = full
        .tracer
        .events()
        .iter()
        .filter(|e| e.kind == EventKind::Execute)
        .map(|e| e.payload)
        .sum();
    assert_eq!(traced, full.metrics.shard_cycles.load(o));

    off.shutdown();
    full.shutdown();
}

/// Satellite: the e2e metrics snapshot serializes through the
/// dependency-free JSON writer and parses back with the documented
/// schema — counters, per-op-kind latency (TTFT = prefill,
/// TPOT = decode), queue depth, and per-device KV gauges.
#[test]
fn metrics_snapshot_round_trips_end_to_end() {
    let coord = Coordinator::start(cfg(BackendKind::Reference, TraceLevel::Summary, 1)).unwrap();
    let (seq, d, heads, kv) = (32usize, 16usize, 4usize, 2usize);
    for i in 0..2u64 {
        let req = gqa_req(300 + i, i, seq, d, heads, kv);
        coord.submit_wait(req).unwrap().output.expect("stateless serving");
    }
    let mut rng = SplitMix64::new(31);
    coord
        .submit_wait(
            AttentionRequest::prefill(
                10,
                5,
                seq,
                d,
                heads,
                kv,
                rng.normal_matrix(heads * seq, d),
                rng.normal_matrix(kv * seq, d),
                rng.normal_matrix(kv * seq, d),
            )
            .with_mask(MaskKind::Causal),
        )
        .unwrap()
        .output
        .expect("prefill");
    coord
        .submit_wait(AttentionRequest::decode(
            11,
            5,
            0,
            d,
            heads,
            kv,
            rng.normal_matrix(heads, d),
            rng.normal_matrix(kv, d),
            rng.normal_matrix(kv, d),
        ))
        .unwrap()
        .output
        .expect("decode");
    coord.submit_wait(AttentionRequest::close(12, 5)).unwrap();

    let snap = coord.metrics.snapshot();
    let text = snap.to_json().pretty();
    let back = fsa::telemetry::json::parse(&text).unwrap();

    let c = back.get("counters").unwrap();
    assert_eq!(c.get("submitted").unwrap().as_u64(), Some(5));
    assert_eq!(c.get("completed").unwrap().as_u64(), Some(5));
    assert_eq!(c.get("failed").unwrap().as_u64(), Some(0));
    assert_eq!(c.get("latency_samples").unwrap().as_u64(), Some(5));
    assert_eq!(c.get("unknown_dispatches").unwrap().as_u64(), Some(0));
    assert_eq!(
        c.get("reference_dispatches").unwrap().as_u64().unwrap(),
        c.get("head_shards").unwrap().as_u64().unwrap(),
        "every shard dispatched on the reference engine"
    );

    // TTFT is the prefill histogram, TPOT the decode one.
    assert_eq!(back.get("ttft_ns").unwrap().get("count").unwrap().as_u64(), Some(1));
    assert_eq!(back.get("tpot_ns").unwrap().get("count").unwrap().as_u64(), Some(1));
    let kinds = back.get("op_kinds").unwrap();
    assert_eq!(kinds.get("stateless").unwrap().get("count").unwrap().as_u64(), Some(2));
    assert_eq!(kinds.get("close").unwrap().get("count").unwrap().as_u64(), Some(1));

    // Queue depth is sampled per envelope the scheduler resolved (5
    // here) PLUS once per working scheduler iteration (DESIGN.md §10),
    // so the count has a floor, not an exact value.
    assert!(back.get("queue_depth").unwrap().get("count").unwrap().as_u64().unwrap() >= 5);

    // The single device gauged its KV cache at the configured capacity.
    let kv_gauges = back.get("kv").unwrap().as_arr().unwrap();
    assert_eq!(kv_gauges.len(), 1);
    assert_eq!(kv_gauges[0].get("device").unwrap().as_u64(), Some(0));
    assert_eq!(
        kv_gauges[0].get("capacity_pages").unwrap().as_u64(),
        Some(RunConfig::default().kv_cache_pages as u64)
    );

    // Summary-level tracing counted spans without retaining events.
    assert!(coord.tracer.enabled());
    assert!(coord.tracer.count(EventKind::Admit) > 0);
    assert!(coord.tracer.events().is_empty());

    coord.shutdown();
}
