//! Coordinator integration tests: end-to-end serving over simulated FSA
//! devices with PJRT numerics, plus failure-injection paths.
//!
//! Requires `make artifacts` (skips gracefully when absent, like the
//! runtime itself does).

use std::path::Path;

use fsa::config::RunConfig;
use fsa::coordinator::request::AttentionRequest;
use fsa::coordinator::Coordinator;
use fsa::numerics::reference::{mat_error, Mat};
use fsa::numerics::SplitMix64;
use fsa::runtime::Runtime;

fn artifacts_ready() -> bool {
    Path::new("artifacts/manifest.txt").exists()
}

fn cfg(devices: usize) -> RunConfig {
    RunConfig {
        devices,
        max_batch: 4,
        batch_timeout_cycles: 50_000,
        queue_depth: 64,
        artifacts_dir: "artifacts".into(),
        // Strict PJRT: these tests exercise the artifact path and skip
        // when `make artifacts` hasn't run (coordinator_gqa.rs covers
        // the artifact-free reference path).
        backend: fsa::config::BackendKind::Pjrt,
        num_heads: 1,
        num_kv_heads: 1,
        ..RunConfig::default()
    }
}

fn req(rng: &mut SplitMix64, id: u64, seq: usize) -> AttentionRequest {
    let d = 128;
    AttentionRequest::new(
        id,
        seq,
        d,
        rng.normal_matrix(seq, d),
        rng.normal_matrix(seq, d),
        rng.normal_matrix(seq, d),
    )
}

#[test]
fn serves_batch_with_correct_numerics() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let coord = Coordinator::start(cfg(2)).unwrap();
    let mut rng = SplitMix64::new(77);
    let reqs: Vec<AttentionRequest> = (0..6).map(|i| req(&mut rng, i, 128)).collect();
    let rxs: Vec<_> = reqs.iter().map(|r| coord.submit(r.clone()).unwrap()).collect();

    let mut verifier = Runtime::new(Path::new("artifacts")).unwrap();
    for (r, rx) in reqs.iter().zip(rxs) {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.id, r.id);
        let out = resp.output.as_ref().expect("numerics ok").clone();
        let want = verifier
            .execute_attention("sdpa_L128_d128", &r.q, &r.k, &r.v)
            .unwrap();
        let err = mat_error(&Mat::new(128, 128, out), &Mat::new(128, 128, want));
        assert!(err.mae < 1e-2, "request {}: {err:?}", r.id);
        assert!(resp.device_cycles > 0);
    }
    // No request lost, none failed.
    assert_eq!(
        coord.metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
        6
    );
    assert_eq!(coord.metrics.failed.load(std::sync::atomic::Ordering::Relaxed), 0);
    coord.shutdown();
}

#[test]
fn unknown_seq_len_fails_cleanly_without_poisoning_the_pool() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let coord = Coordinator::start(cfg(1)).unwrap();
    let mut rng = SplitMix64::new(9);
    // 256 is not an artifact bucket (128/512/2048/4096 are shipped).
    let bad = coord.submit(req(&mut rng, 1, 256)).unwrap();
    let resp = bad.recv().unwrap();
    assert!(resp.output.is_err(), "1 should fail: no exact artifact");
    assert!(resp.output.unwrap_err().contains("strict mode"));
    // The pool still serves good requests afterwards.
    let good = coord.submit(req(&mut rng, 2, 128)).unwrap();
    assert!(good.recv().unwrap().output.is_ok());
    coord.shutdown();
}

#[test]
fn padded_requests_are_rejected_by_mask_free_artifacts() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let coord = Coordinator::start(cfg(1)).unwrap();
    let mut rng = SplitMix64::new(10);
    // `padded()` is now exact: it stamps a PaddingKeys mask so the
    // padded key rows are excluded from the softmax (DESIGN.md §6).
    // The AOT artifacts take no mask input, so a strict PJRT pool
    // rejects the request with an explicit error instead of silently
    // serving the old residual-weight approximation; the reference
    // backend serves it bitwise-exactly (rust/tests/coordinator_masked.rs).
    let original = req(&mut rng, 3, 100);
    let padded = original.padded(128);
    assert!(!padded.mask.is_none(), "padded() must stamp the mask");
    let resp = coord.submit_wait(padded).unwrap();
    let err = resp.output.expect_err("mask-free artifacts must reject");
    assert!(err.contains("no attention mask"), "{err}");
    // The pool still serves exact-bucket requests afterwards.
    let good = coord.submit_wait(req(&mut rng, 4, 128)).unwrap();
    assert!(good.output.is_ok());
    coord.shutdown();
}

#[test]
fn backpressure_on_full_queue() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut c = cfg(1);
    c.queue_depth = 2;
    let coord = Coordinator::start(c).unwrap();
    let mut rng = SplitMix64::new(11);
    // Flood fast; some submits must hit backpressure instead of hanging.
    let mut accepted = Vec::new();
    let mut rejected = 0usize;
    for i in 0..64 {
        match coord.submit(req(&mut rng, i, 128)) {
            Ok(rx) => accepted.push(rx),
            Err(e) => {
                assert!(e.to_string().contains("backpressure"), "{e}");
                rejected += 1;
            }
        }
    }
    // Every accepted request completes exactly once; any rejection must
    // have been a clean backpressure error (whether the burst outpaces
    // the batcher's drain is timing-dependent, so zero rejections is
    // also a legal outcome — the invariant is no loss, no hang).
    let n_accepted = accepted.len();
    for rx in accepted {
        let _ = rx.recv().expect("accepted requests must complete");
    }
    assert_eq!(n_accepted + rejected, 64);
    coord.shutdown();
}

#[test]
fn missing_artifacts_dir_fails_fast() {
    let mut c = cfg(1);
    c.artifacts_dir = "/nonexistent/path".into();
    assert!(Coordinator::start(c).is_err());
}
