//! End-to-end sequence-parallel serving tests (DESIGN.md §7) on the
//! reference backend: K/V split into tile-aligned chunks across the
//! pool, per-chunk partials merged exactly at gather.
//!
//! The bitwise contract under test: the gathered output is a pure
//! function of the chunk grid — **invariant to the device count and to
//! which device served which chunk** — and equals the host-side
//! chunked oracle bit for bit; `seq_shards = 1` stays bitwise the
//! legacy path.  (Across *different* shard counts the result is
//! mathematically equal but, like any FP reassociation, not bitwise —
//! parity with dense SDPA is asserted instead.)  No PJRT and no
//! artifacts, so these run in every environment.

use fsa::config::{BackendKind, RunConfig};
use fsa::coordinator::request::{AttentionRequest, AttentionResponse};
use fsa::coordinator::Coordinator;
use fsa::mask::MaskKind;
use fsa::numerics::pwl::PwlExp2;
use fsa::numerics::reference::{
    decode_pwl_partial, flash_pwl_masked, flash_pwl_partial, mat_error, merge_partials, sdpa_masked,
    Exp2, FlashPartial, Mat,
};
use fsa::numerics::SplitMix64;
use fsa::schedule::live_chunk_ranges;

/// Array dim / PWL segments of the builtin `fsa` device config the
/// workers run: the oracle must tile and merge the same way.
const ARRAY: usize = 128;
const SEGMENTS: usize = 8;

fn cfg(devices: usize, seq_shards: usize) -> RunConfig {
    RunConfig {
        devices,
        max_batch: 8,
        batch_timeout_cycles: 50_000,
        queue_depth: 64,
        backend: BackendKind::Reference,
        num_heads: 4,
        num_kv_heads: 2,
        seq_shards,
        ..RunConfig::default()
    }
}

fn gqa_req(
    rng: &mut SplitMix64,
    id: u64,
    seq: usize,
    d: usize,
    heads: usize,
    kv: usize,
) -> AttentionRequest {
    AttentionRequest::gqa(
        id,
        seq,
        d,
        heads,
        kv,
        rng.normal_matrix(heads * seq, d),
        rng.normal_matrix(kv * seq, d),
        rng.normal_matrix(kv * seq, d),
    )
}

/// Host-side oracle of one head served at `seq_shards`: per-chunk
/// partials over the same grid the batcher builds, merged in chunk
/// order with the same PWL exp2 — what the pool must reproduce bitwise.
fn oracle_head(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    seq: usize,
    d: usize,
    mask: MaskKind,
    seq_shards: usize,
) -> Vec<f32> {
    if seq_shards == 1 {
        let qm = Mat::new(seq, d, q.to_vec());
        let km = Mat::new(seq, d, k.to_vec());
        let vm = Mat::new(seq, d, v.to_vec());
        return flash_pwl_masked(&qm, &km, &vm, ARRAY, ARRAY, SEGMENTS, mask).data;
    }
    let parts: Vec<FlashPartial> = live_chunk_ranges(seq, seq, seq, seq_shards, mask)
        .into_iter()
        .map(|(_, (start, len))| {
            flash_pwl_partial(
                &Mat::new(seq, d, q.to_vec()),
                &Mat::new(len, d, k[start * d..(start + len) * d].to_vec()),
                &Mat::new(len, d, v[start * d..(start + len) * d].to_vec()),
                ARRAY,
                ARRAY,
                SEGMENTS,
                mask,
                start,
                seq,
            )
        })
        .collect();
    merge_partials(&parts, &Exp2::PwlF16(PwlExp2::new(SEGMENTS))).data
}

fn serve_one(devices: usize, seq_shards: usize, req: AttentionRequest) -> AttentionResponse {
    let coord = Coordinator::start(cfg(devices, seq_shards)).unwrap();
    let resp = coord.submit_wait(req).unwrap();
    coord.shutdown();
    resp
}

/// Acceptance: seq_shards ∈ {2, 4} serving is bitwise identical to
/// single-device serving (same shard count — the chunk grid, not the
/// placement, defines the numerics) for {none, causal} across three
/// shapes, and bitwise equal to the host-side chunked oracle; the
/// merged result stays within the Table-2 band of masked dense SDPA.
#[test]
fn seq_sharded_serving_is_bitwise_placement_invariant() {
    let mut rng = SplitMix64::new(81);
    for &(seq, d, heads, kv) in &[(64usize, 16usize, 4usize, 2usize), (96, 32, 2, 1), (40, 16, 4, 4)]
    {
        for mask in [MaskKind::None, MaskKind::Causal] {
            let req = gqa_req(&mut rng, 1, seq, d, heads, kv).with_mask(mask);
            for shards in [2usize, 4] {
                let single = serve_one(1, shards, req.clone());
                let multi = serve_one(3, shards, req.clone());
                let out1 = single.output.expect("1-device serving succeeds");
                let out3 = multi.output.expect("3-device serving succeeds");
                assert_eq!(
                    out1, out3,
                    "L={seq} d={d} {mask:?} shards={shards}: output depends on placement"
                );
                assert_eq!(multi.stats.seq_chunks, shards.min(seq));
                assert_eq!(multi.shards, heads * multi.stats.seq_chunks);
                assert_eq!(multi.stats.merge_steps, heads * (multi.stats.seq_chunks - 1));
                assert!(
                    multi.devices_used.len() > 1,
                    "chunks must actually scatter across the pool"
                );

                for h in 0..heads {
                    let kvh = h / (heads / kv);
                    let stride = seq * d;
                    let want = oracle_head(
                        &req.q[h * stride..(h + 1) * stride],
                        &req.k[kvh * stride..(kvh + 1) * stride],
                        &req.v[kvh * stride..(kvh + 1) * stride],
                        seq,
                        d,
                        mask,
                        shards,
                    );
                    assert_eq!(
                        &out1[h * stride..(h + 1) * stride],
                        &want[..],
                        "L={seq} {mask:?} shards={shards} head {h}: diverged from the oracle"
                    );
                    // Numerics parity: the merged result is the same
                    // attention, inside the PWL error band.
                    let dense = sdpa_masked(
                        &Mat::new(seq, d, req.q[h * stride..(h + 1) * stride].to_vec()),
                        &Mat::new(seq, d, req.k[kvh * stride..(kvh + 1) * stride].to_vec()),
                        &Mat::new(seq, d, req.v[kvh * stride..(kvh + 1) * stride].to_vec()),
                        mask,
                    );
                    let got = Mat::new(seq, d, want);
                    let err = mat_error(&got, &dense);
                    assert!(err.mae < 3e-2, "head {h}: {err:?}");
                }
            }
            // seq_shards = 1 stays bitwise the legacy whole-head path.
            let legacy = serve_one(2, 1, req.clone()).output.unwrap();
            let h0 = oracle_head(
                &req.q[..seq * d],
                &req.k[..seq * d],
                &req.v[..seq * d],
                seq,
                d,
                mask,
                1,
            );
            assert_eq!(&legacy[..seq * d], &h0[..]);
        }
    }
}

/// A key-padding mask with a dead tail: fully-masked chunks are never
/// dispatched, the live chunks still produce the exact (bitwise) padded
/// result.
#[test]
fn dead_chunks_are_skipped_and_padding_stays_exact() {
    let (seq, d, heads, kv) = (64usize, 16usize, 4usize, 2usize);
    let mut rng = SplitMix64::new(82);
    // valid=20 kills chunks [32,48) and [48,64) of a 4-way split.
    let req =
        gqa_req(&mut rng, 1, seq, d, heads, kv).with_mask(MaskKind::PaddingKeys { valid: 20 });
    let resp = serve_one(2, 4, req.clone());
    assert_eq!(resp.stats.seq_chunks, 2, "two live chunks out of four");
    assert_eq!(resp.shards, heads * 2);
    let out = resp.output.unwrap();
    for h in 0..heads {
        let kvh = h / (heads / kv);
        let stride = seq * d;
        let want = oracle_head(
            &req.q[h * stride..(h + 1) * stride],
            &req.k[kvh * stride..(kvh + 1) * stride],
            &req.v[kvh * stride..(kvh + 1) * stride],
            seq,
            d,
            MaskKind::PaddingKeys { valid: 20 },
            4,
        );
        assert_eq!(&out[h * stride..(h + 1) * stride], &want[..], "head {h}");
    }

    // A fully-masked operator (valid = 0) degenerates to one legacy
    // shard per head and the defined zero output.
    let req = gqa_req(&mut rng, 2, seq, d, heads, kv).with_mask(MaskKind::PaddingKeys { valid: 0 });
    let resp = serve_one(2, 4, req);
    assert_eq!(resp.stats.seq_chunks, 1);
    assert!(resp.output.unwrap().iter().all(|&x| x == 0.0));
}

/// Acceptance: a causal prefill → split-KV decode session.  Every
/// decode step runs one partial row per chunk device over the session's
/// pages (the prefill-fixed chunk grid, last chunk growing) and the
/// merged step output is bitwise invariant to the pool size — and
/// bitwise equal to the host-side split-KV oracle.
#[test]
fn causal_prefill_split_kv_decode_is_bitwise_placement_invariant() {
    let (seq, d, heads, kv, steps, shards) = (32usize, 16usize, 4usize, 2usize, 5usize, 2usize);
    let run = |devices: usize| -> (Vec<Vec<f32>>, usize, usize) {
        let coord = Coordinator::start(cfg(devices, shards)).unwrap();
        let mut rng = SplitMix64::new(83); // same tensors per pool size
        let prefill = AttentionRequest::prefill(
            1,
            9,
            seq,
            d,
            heads,
            kv,
            rng.normal_matrix(heads * seq, d),
            rng.normal_matrix(kv * seq, d),
            rng.normal_matrix(kv * seq, d),
        )
        .with_mask(MaskKind::Causal);
        let mut outs = vec![coord.submit_wait(prefill).unwrap().output.expect("prefill")];
        let (mut hits, mut misses) = (0usize, 0usize);
        for step in 0..steps as u64 {
            let resp = coord
                .submit_wait(AttentionRequest::decode(
                    100 + step,
                    9,
                    step,
                    d,
                    heads,
                    kv,
                    rng.normal_matrix(heads, d),
                    rng.normal_matrix(kv, d),
                    rng.normal_matrix(kv, d),
                ))
                .unwrap();
            hits += resp.stats.kv_hits;
            misses += resp.stats.kv_misses;
            assert_eq!(resp.stats.seq_chunks, shards, "split-KV decode runs one row per chunk");
            outs.push(resp.output.expect("decode step"));
        }
        coord.submit_wait(AttentionRequest::close(999, 9)).unwrap();
        coord.shutdown();
        (outs, hits, misses)
    };

    let (one, hits1, _) = run(1);
    let (two, hits2, _) = run(2);
    assert_eq!(one, two, "decode outputs depend on the pool size");
    // The per-chunk page streams serve most shards from cache.
    assert!(hits1 > 0 && hits2 > 0, "split-KV decode must hit its chunk pages");

    // Host-side split-KV oracle: client mirror of the K/V history,
    // ranges on the prefill basis, one partial per range, merged in
    // range order.
    let mut rng = SplitMix64::new(83);
    let mut kh: Vec<Vec<f32>> = vec![Vec::new(); kv];
    let mut vh: Vec<Vec<f32>> = vec![Vec::new(); kv];
    // Mirror the prefill draws in order (q unused by the decode oracle).
    let _q = rng.normal_matrix(heads * seq, d);
    let k = rng.normal_matrix(kv * seq, d);
    let v = rng.normal_matrix(kv * seq, d);
    for h in 0..kv {
        kh[h].extend_from_slice(&k[h * seq * d..(h + 1) * seq * d]);
        vh[h].extend_from_slice(&v[h * seq * d..(h + 1) * seq * d]);
    }
    let exp2 = Exp2::PwlF16(PwlExp2::new(SEGMENTS));
    for (step, got) in one.iter().skip(1).enumerate() {
        let qs = rng.normal_matrix(heads, d);
        let ks = rng.normal_matrix(kv, d);
        let vs = rng.normal_matrix(kv, d);
        for h in 0..kv {
            kh[h].extend_from_slice(&ks[h * d..(h + 1) * d]);
            vh[h].extend_from_slice(&vs[h * d..(h + 1) * d]);
        }
        let prefix = seq + 1 + step;
        for h in 0..heads {
            let kvh = h / (heads / kv);
            let parts: Vec<FlashPartial> =
                live_chunk_ranges(1, prefix, seq, shards, MaskKind::None)
                    .into_iter()
                    .map(|(_, (start, len))| {
                        decode_pwl_partial(
                            &qs[h * d..(h + 1) * d],
                            &kh[kvh][start * d..(start + len) * d],
                            &vh[kvh][start * d..(start + len) * d],
                            d,
                            ARRAY,
                            SEGMENTS,
                        )
                    })
                    .collect();
            let want = merge_partials(&parts, &exp2);
            assert_eq!(
                &got[h * d..(h + 1) * d],
                &want.data[..],
                "step {step} head {h}: diverged from the split-KV oracle"
            );
        }
    }
}
