//! End-to-end multi-head / GQA serving tests on the reference backend:
//! no PJRT, no artifacts — the full coordinator path (ingress →
//! batcher shard explosion → affinity router → device pool → gather)
//! runs on the in-crate `flash_pwl` device twin, so these execute in
//! every environment.

use fsa::config::{BackendKind, RunConfig};
use fsa::coordinator::request::AttentionRequest;
use fsa::coordinator::Coordinator;
use fsa::numerics::reference::{flash_pwl, Mat};
use fsa::numerics::SplitMix64;

fn cfg(devices: usize) -> RunConfig {
    RunConfig {
        devices,
        max_batch: 8,
        batch_timeout_cycles: 50_000,
        queue_depth: 64,
        artifacts_dir: "artifacts".into(),
        backend: BackendKind::Reference,
        num_heads: 8,
        num_kv_heads: 2,
        ..RunConfig::default()
    }
}

fn gqa_req(rng: &mut SplitMix64, id: u64, seq: usize, d: usize, heads: usize, kv: usize) -> AttentionRequest {
    AttentionRequest::gqa(
        id,
        seq,
        d,
        heads,
        kv,
        rng.normal_matrix(heads * seq, d),
        rng.normal_matrix(kv * seq, d),
        rng.normal_matrix(kv * seq, d),
    )
}

#[test]
fn gqa_request_shards_across_devices_and_matches_single_device_reference() {
    let (seq, d, heads, kv) = (64usize, 32usize, 8usize, 2usize);
    let mut rng = SplitMix64::new(42);
    let req = gqa_req(&mut rng, 1, seq, d, heads, kv);

    // Serve the same request on a pool of 3 and on a single device.
    let pool = Coordinator::start(cfg(3)).unwrap();
    let resp = pool.submit_wait(req.clone()).unwrap();
    let single = Coordinator::start(cfg(1)).unwrap();
    let resp1 = single.submit_wait(req.clone()).unwrap();

    // Sharded across >= 2 workers, gathered into one response.
    assert!(
        resp.devices_used.len() >= 2,
        "expected scatter across devices, got {:?}",
        resp.devices_used
    );
    assert_eq!(resp.shards, heads);
    assert_eq!(resp.num_heads, heads);
    assert_eq!(resp.num_kv_heads, kv);

    // The gathered pool output is bitwise identical to the
    // single-device run (deterministic numerics, same per-head path).
    let out = resp.output.expect("pool numerics ok");
    let out1 = resp1.output.expect("single-device numerics ok");
    assert_eq!(out, out1, "head sharding must not change numerics");

    // And both match the flash_pwl device twin computed head by head.
    assert_eq!(out.len(), heads * seq * d);
    for h in 0..heads {
        let (k, v) = req.head_kv(req.kv_head_for(h));
        let want = flash_pwl(
            &Mat::new(seq, d, req.head_q(h).to_vec()),
            &Mat::new(seq, d, k.to_vec()),
            &Mat::new(seq, d, v.to_vec()),
            seq,
            seq,
            8,
        );
        assert_eq!(&out[h * seq * d..(h + 1) * seq * d], &want.data[..], "head {h}");
    }

    // Whole-operator accounting: cost is summed per head, the critical
    // path can't exceed it, and utilization is a sane ratio.
    assert!(resp.device_cycles > 0);
    assert_eq!(resp.device_cycles % heads as u64, 0, "identical per-head work");
    assert!(resp.critical_path_cycles <= resp.device_cycles);
    assert!(resp.critical_path_cycles >= resp.device_cycles / 3);
    assert!(resp.utilization > 0.0 && resp.utilization < 1.0);
    // Single device: critical path == total cost.
    assert_eq!(resp1.critical_path_cycles, resp1.device_cycles);

    // Shard-level metrics: 8 shards counted, request counted once, and
    // per-shard cycle accounting agrees with the gathered aggregate.
    let m = &pool.metrics;
    let o = std::sync::atomic::Ordering::Relaxed;
    assert_eq!(m.head_shards.load(o), heads);
    assert_eq!(m.completed.load(o), 1);
    assert_eq!(m.multi_head_requests.load(o), 1);
    assert_eq!(m.failed.load(o), 0);
    assert_eq!(m.shard_cycles.load(o), m.device_cycles.load(o));

    pool.shutdown();
    single.shutdown();
}

#[test]
fn mixed_single_and_multi_head_traffic_coexists() {
    let coord = Coordinator::start(cfg(2)).unwrap();
    let mut rng = SplitMix64::new(7);
    let (seq, d) = (32usize, 16usize);

    let single = gqa_req(&mut rng, 1, seq, d, 1, 1);
    let multi = gqa_req(&mut rng, 2, seq, d, 4, 4);
    let rx1 = coord.submit(single).unwrap();
    let rx2 = coord.submit(multi).unwrap();
    let r1 = rx1.recv().unwrap();
    let r2 = rx2.recv().unwrap();

    assert_eq!(r1.shards, 1);
    assert_eq!(r1.output.as_ref().unwrap().len(), seq * d);
    assert_eq!(r1.devices_used.len(), 1);
    assert_eq!(r2.shards, 4);
    assert_eq!(r2.output.as_ref().unwrap().len(), 4 * seq * d);

    let o = std::sync::atomic::Ordering::Relaxed;
    assert_eq!(coord.metrics.head_shards.load(o), 5);
    assert_eq!(coord.metrics.completed.load(o), 2);
    assert_eq!(coord.metrics.multi_head_requests.load(o), 1);
    coord.shutdown();
}

#[test]
fn reference_backend_needs_no_artifacts_dir() {
    let mut c = cfg(1);
    c.artifacts_dir = "/nonexistent/path".into();
    let coord = Coordinator::start(c).unwrap();
    let mut rng = SplitMix64::new(9);
    let resp = coord.submit_wait(gqa_req(&mut rng, 1, 16, 8, 2, 1)).unwrap();
    assert!(resp.output.is_ok());
    coord.shutdown();
}

#[test]
fn pjrt_backend_still_fails_fast_without_artifacts() {
    let mut c = cfg(1);
    c.backend = BackendKind::Pjrt;
    c.artifacts_dir = "/nonexistent/path".into();
    assert!(Coordinator::start(c).is_err());
}
