//! End-to-end decode-phase serving tests (DESIGN.md §5) on the
//! reference backend: the full coordinator path — session lifecycle at
//! the admission gate, sticky affinity routing, per-device paged KV
//! caches,
//! single-query-row device numerics, whole-operator gather — with no
//! PJRT and no artifacts, so these run in every environment.
//!
//! The load-bearing invariant (ISSUE acceptance): a session prefilled
//! at L=256 and decoded for 64+ steps produces outputs **bitwise
//! identical** to stateless full-prefix recomputation at every step,
//! including across an eviction → recompute → re-cache cycle.

use fsa::config::{BackendKind, EvictionPolicy, RunConfig};
use fsa::coordinator::request::AttentionRequest;
use fsa::coordinator::Coordinator;
use fsa::numerics::reference::decode_pwl;
use fsa::numerics::SplitMix64;
use fsa::perfmodel::fsa_decode_perf;
use fsa::schedule::Variant;

/// Array dim / PWL segments of the builtin `fsa` device config the
/// workers run: the stateless oracle must tile the same way.
const ARRAY: usize = 128;
const SEGMENTS: usize = 8;

fn cfg(devices: usize, kv_pages: usize, page_size: usize) -> RunConfig {
    RunConfig {
        devices,
        max_batch: 8,
        batch_timeout_cycles: 50_000,
        queue_depth: 64,
        artifacts_dir: "artifacts".into(),
        backend: BackendKind::Reference,
        num_heads: 4,
        num_kv_heads: 2,
        kv_cache_pages: kv_pages,
        kv_page_size: page_size,
        kv_eviction: EvictionPolicy::Lru,
        ..RunConfig::default()
    }
}

/// Client-side mirror of one session: the full K/V history per KV
/// head, used for stateless full-prefix recomputation.
struct Mirror {
    session: u64,
    heads: usize,
    kv_heads: usize,
    d: usize,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    step: u64,
}

impl Mirror {
    fn new(session: u64, heads: usize, kv_heads: usize, d: usize) -> Mirror {
        Mirror {
            session,
            heads,
            kv_heads,
            d,
            k: vec![Vec::new(); kv_heads],
            v: vec![Vec::new(); kv_heads],
            step: 0,
        }
    }

    fn prefill(&mut self, rng: &mut SplitMix64, id: u64, seq: usize) -> AttentionRequest {
        let q = rng.normal_matrix(self.heads * seq, self.d);
        let k = rng.normal_matrix(self.kv_heads * seq, self.d);
        let v = rng.normal_matrix(self.kv_heads * seq, self.d);
        for h in 0..self.kv_heads {
            self.k[h].extend_from_slice(&k[h * seq * self.d..(h + 1) * seq * self.d]);
            self.v[h].extend_from_slice(&v[h * seq * self.d..(h + 1) * seq * self.d]);
        }
        AttentionRequest::prefill(id, self.session, seq, self.d, self.heads, self.kv_heads, q, k, v)
    }

    /// Build the next decode request and return it with the per-head
    /// stateless oracle outputs over the full prefix (computed exactly
    /// as the device's reference backend computes them: `decode_pwl`
    /// tiled at the array size).
    fn decode(&mut self, rng: &mut SplitMix64, id: u64) -> (AttentionRequest, Vec<f32>) {
        let d = self.d;
        let q = rng.normal_matrix(self.heads, d);
        let k = rng.normal_matrix(self.kv_heads, d);
        let v = rng.normal_matrix(self.kv_heads, d);
        for h in 0..self.kv_heads {
            self.k[h].extend_from_slice(&k[h * d..(h + 1) * d]);
            self.v[h].extend_from_slice(&v[h * d..(h + 1) * d]);
        }
        let group = self.heads / self.kv_heads;
        let mut want = Vec::with_capacity(self.heads * d);
        for head in 0..self.heads {
            let kv = head / group;
            want.extend_from_slice(&decode_pwl(
                &q[head * d..(head + 1) * d],
                &self.k[kv],
                &self.v[kv],
                d,
                ARRAY,
                SEGMENTS,
            ));
        }
        let req =
            AttentionRequest::decode(id, self.session, self.step, d, self.heads, self.kv_heads, q, k, v);
        self.step += 1;
        (req, want)
    }
}

/// ISSUE acceptance: prefill at L=256, decode 64 steps, every step
/// bitwise-identical to stateless recomputation; all steps after the
/// prefill are cache hits on an ample cache.
#[test]
fn decode_session_is_bitwise_stateless_recompute() {
    let (seq, d, steps) = (256usize, 32usize, 64usize);
    let coord = Coordinator::start(cfg(2, 256, 16)).unwrap();
    let mut rng = SplitMix64::new(2027);
    let mut mirror = Mirror::new(1, 4, 2, d);

    let resp = coord.submit_wait(mirror.prefill(&mut rng, 1, seq)).unwrap();
    assert!(resp.output.is_ok(), "{:?}", resp.output);
    assert_eq!(resp.shards, 4);

    let mut hits = 0usize;
    let mut devices_seen = Vec::new();
    for i in 0..steps {
        let (req, want) = mirror.decode(&mut rng, 100 + i as u64);
        let resp = coord.submit_wait(req).unwrap();
        let got = resp.output.expect("decode step succeeds");
        assert_eq!(got, want, "step {i} diverged from stateless recompute");
        assert_eq!(resp.shards, 4);
        hits += resp.stats.kv_hits;
        devices_seen.push(resp.devices_used.clone());
    }
    // Every decode shard after the prefill was served from pages.
    assert_eq!(hits, 4 * steps, "expected pure hits on an ample cache");
    // Sticky placement: each step lands on the same device set.
    assert!(devices_seen.windows(2).all(|w| w[0] == w[1]), "{devices_seen:?}");

    // Lifecycle: close succeeds once, then the session is gone.
    let resp = coord.submit_wait(AttentionRequest::close(900, 1)).unwrap();
    assert!(resp.output.is_ok());
    assert!(!coord.sessions.contains(1));
    let resp = coord.submit_wait(AttentionRequest::close(901, 1)).unwrap();
    assert!(resp.output.is_err(), "double close must error");

    let o = std::sync::atomic::Ordering::Relaxed;
    assert_eq!(coord.metrics.sessions_opened.load(o), 1);
    assert_eq!(coord.metrics.sessions_closed.load(o), 1);
    assert_eq!(coord.metrics.decode_steps.load(o), steps);
    assert_eq!(coord.metrics.kv_hits.load(o), (4 * steps) as u64);
    assert_eq!(coord.metrics.kv_misses.load(o), 0);
    coord.shutdown();
}

/// The eviction → recompute → re-cache cycle: a second session's
/// prefill evicts the first from a tiny cache; the first session's
/// next step misses (recompute fallback, still bitwise-exact) and
/// re-caches, so the step after that hits again.
#[test]
fn eviction_recompute_recache_cycle_stays_bitwise_exact() {
    let (seq, d) = (64usize, 16usize);
    // One device so placement is deterministic.  Each session needs
    // ceil(64/16) = 4 pages per KV stream x 2 KV heads = 8 pages (+1
    // as it grows); 12 pages cannot hold two sessions.
    let coord = Coordinator::start(cfg(1, 12, 16)).unwrap();
    let mut rng = SplitMix64::new(99);
    let mut a = Mirror::new(10, 4, 2, d);
    let mut b = Mirror::new(20, 4, 2, d);

    assert!(coord.submit_wait(a.prefill(&mut rng, 1, seq)).unwrap().output.is_ok());

    // A decodes warm: pure hits.
    let (req, want) = a.decode(&mut rng, 2);
    let resp = coord.submit_wait(req).unwrap();
    assert_eq!(resp.output.unwrap(), want);
    assert_eq!((resp.stats.kv_hits, resp.stats.kv_misses), (4, 0));

    // B's prefill forces A's streams out (LRU).
    assert!(coord.submit_wait(b.prefill(&mut rng, 3, seq)).unwrap().output.is_ok());
    let o = std::sync::atomic::Ordering::Relaxed;
    assert!(coord.metrics.kv_evictions.load(o) > 0, "B must evict A");

    // A's next step: each KV group's first shard misses, recomputes
    // from the host tier and re-caches; its groupmate then hits the
    // re-cached stream.  Outputs stay identical either way.
    let (req, want) = a.decode(&mut rng, 4);
    let resp = coord.submit_wait(req).unwrap();
    assert_eq!(resp.output.unwrap(), want, "miss path diverged");
    assert_eq!(
        (resp.stats.kv_misses, resp.stats.kv_hits),
        (2, 2),
        "one miss + one groupmate hit per KV group"
    );

    // Re-cached: the following step hits again (B in turn was evicted
    // by A's re-cache, completing the cycle).
    let (req, want) = a.decode(&mut rng, 5);
    let resp = coord.submit_wait(req).unwrap();
    assert_eq!(resp.output.unwrap(), want);
    assert_eq!((resp.stats.kv_hits, resp.stats.kv_misses), (4, 0));

    // And B now misses, recomputes, stays exact.
    let (req, want) = b.decode(&mut rng, 6);
    let resp = coord.submit_wait(req).unwrap();
    assert_eq!(resp.output.unwrap(), want);
    assert_eq!(resp.stats.kv_misses, 2);

    coord.shutdown();
}

/// Session-id reuse after close: device caches reap closed streams
/// lazily, so a same-length leftover of the dead predecessor can
/// still be resident when the reused id prefills on the same device.
/// The incarnation epoch must keep it from ever being served.
#[test]
fn reused_session_id_never_serves_the_dead_predecessors_kv() {
    let (seq, d) = (64usize, 16usize);
    // One device, ample cache: the old streams stay resident (no
    // capacity pressure ever reaps them) — the worst case for reuse.
    let coord = Coordinator::start(cfg(1, 64, 16)).unwrap();
    let mut rng = SplitMix64::new(7);

    // First incarnation of id 5: prefill, then close immediately —
    // the resident dead stream keeps exactly the prefill length, so
    // an epoch-blind "groupmate already inserted" length check would
    // skip the new prefill's insert (the original bug).
    let mut first = Mirror::new(5, 4, 2, d);
    assert!(coord.submit_wait(first.prefill(&mut rng, 1, seq)).unwrap().output.is_ok());
    assert!(coord.submit_wait(AttentionRequest::close(3, 5)).unwrap().output.is_ok());

    // Second incarnation, same id, same shapes, fresh K/V.  Its
    // prefill has the same length as the resident dead stream — the
    // epoch check must force a replace, not a skip.
    let mut second = Mirror::new(5, 4, 2, d);
    assert!(coord.submit_wait(second.prefill(&mut rng, 4, seq)).unwrap().output.is_ok());
    for i in 0..3 {
        let (req, want) = second.decode(&mut rng, 10 + i);
        let resp = coord.submit_wait(req).unwrap();
        assert_eq!(
            resp.output.unwrap(),
            want,
            "step {i} of the reused id served stale predecessor K/V"
        );
        assert_eq!((resp.stats.kv_hits, resp.stats.kv_misses), (4, 0), "fresh streams must hit");
    }
    coord.shutdown();
}

/// Lifecycle validation is answered with error responses, never
/// panics, and never touches the pool.
#[test]
fn lifecycle_violations_get_error_responses() {
    let d = 8;
    let coord = Coordinator::start(cfg(1, 32, 4)).unwrap();
    let mut rng = SplitMix64::new(5);

    // Decode before prefill.
    let req = AttentionRequest::decode(
        1, 7, 0, d, 4, 2,
        rng.normal_matrix(4, d), rng.normal_matrix(2, d), rng.normal_matrix(2, d),
    );
    let resp = coord.submit_wait(req).unwrap();
    assert!(resp.output.unwrap_err().contains("not open"));

    // Prefill, then a double prefill and an out-of-order step.
    let mut m = Mirror::new(7, 4, 2, d);
    assert!(coord.submit_wait(m.prefill(&mut rng, 2, 8)).unwrap().output.is_ok());
    let mut m2 = Mirror::new(7, 4, 2, d);
    let resp = coord.submit_wait(m2.prefill(&mut rng, 3, 8)).unwrap();
    assert!(resp.output.unwrap_err().contains("already open"));

    let req = AttentionRequest::decode(
        4, 7, 5, d, 4, 2,
        rng.normal_matrix(4, d), rng.normal_matrix(2, d), rng.normal_matrix(2, d),
    );
    let resp = coord.submit_wait(req).unwrap();
    assert!(resp.output.unwrap_err().contains("expected decode step 0"));

    // The valid step still works after the rejected ones.
    let (req, want) = m.decode(&mut rng, 5);
    assert_eq!(coord.submit_wait(req).unwrap().output.unwrap(), want);

    let o = std::sync::atomic::Ordering::Relaxed;
    assert_eq!(coord.metrics.failed.load(o), 3);
    coord.shutdown();
}

/// The perfmodel backs the bench's scaling claim: cached decode cost
/// and bytes are O(L) while the miss recompute is O(L²) — doubling the
/// prefix doubles one and quadruples the other.
#[test]
fn decode_perfmodel_scaling_is_linear_vs_quadratic() {
    let cfg = fsa::config::AccelConfig::builtin("fsa").unwrap();
    let ls = [1024usize, 2048, 4096, 8192];
    let hit: Vec<_> = ls
        .iter()
        .map(|&l| fsa_decode_perf(&cfg, l, 128, true, Variant::DualPath, 8))
        .collect();
    let miss: Vec<_> = ls
        .iter()
        .map(|&l| fsa_decode_perf(&cfg, l, 128, false, Variant::DualPath, 8))
        .collect();
    for w in hit.windows(2) {
        let bytes = w[1].bytes_streamed as f64 / w[0].bytes_streamed as f64;
        let cycles = w[1].step_cycles as f64 / w[0].step_cycles as f64;
        assert!((bytes - 2.0).abs() < 0.05, "O(L) bytes: {bytes}");
        assert!(cycles > 1.7 && cycles < 2.3, "O(L) cycles: {cycles}");
    }
    for w in miss.windows(2) {
        let rc = w[1].recompute_cycles as f64 / w[0].recompute_cycles as f64;
        assert!(rc > 3.4 && rc < 4.6, "O(L²) recompute: {rc}");
    }
}
