//! End-to-end `backend=sim` serving (DESIGN.md §8): the cycle-accurate
//! machine as a first-class pool backend.  The acceptance contract:
//!
//! * causal-masked prefill, decode steps, and `seq_shards = 2` chunked
//!   serving all produce outputs BITWISE-equal to the same requests on
//!   a `backend=reference` pool (same seeds, same array size);
//! * responses are priced from *measured* machine cycles
//!   (`measured_shards == shards`), and those measured cycles agree
//!   with the perfmodel's tile-cycle predictions within the pinned
//!   `SIM_MODEL_BAND`;
//! * per-backend dispatch metrics count every shard under `sim`;
//! * the `sim_max_seq` O(L²) guard rejects over-long requests with an
//!   error naming the knob.
//!
//! Everything runs on a 32-wide array (`RunConfig::array_size`) so the
//! cycle-accurate executions stay in the millisecond range.

use fsa::config::{AccelConfig, BackendKind, RunConfig};
use fsa::coordinator::request::{AttentionRequest, AttentionResponse};
use fsa::coordinator::Coordinator;
use fsa::mask::MaskKind;
use fsa::numerics::SplitMix64;
use fsa::perfmodel::{multi_head_perf_masked, SIM_MODEL_BAND};
use fsa::schedule::Variant;

const N: usize = 32;

fn cfg(backend: BackendKind, devices: usize, seq_shards: usize) -> RunConfig {
    RunConfig {
        devices,
        max_batch: 8,
        batch_timeout_cycles: 50_000,
        queue_depth: 64,
        backend,
        num_heads: 4,
        num_kv_heads: 2,
        seq_shards,
        sim_max_seq: 256,
        array_size: N,
        ..RunConfig::default()
    }
}

fn gqa_req(seed: u64, id: u64, seq: usize, d: usize, heads: usize, kv: usize) -> AttentionRequest {
    let mut rng = SplitMix64::new(seed);
    AttentionRequest::gqa(
        id,
        seq,
        d,
        heads,
        kv,
        rng.normal_matrix(heads * seq, d),
        rng.normal_matrix(kv * seq, d),
        rng.normal_matrix(kv * seq, d),
    )
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Acceptance: stateless serving (unmasked, causal, ragged+padded) on
/// the sim pool is bitwise the reference pool, priced from measured
/// cycles, with the modeled prediction inside the pinned band.
#[test]
fn sim_pool_matches_reference_pool_bitwise_and_prices_measured_cycles() {
    let (heads, kv) = (4usize, 2usize);
    let sim = Coordinator::start(cfg(BackendKind::Sim, 2, 1)).unwrap();
    let reference = Coordinator::start(cfg(BackendKind::Reference, 2, 1)).unwrap();

    let mut checked = 0usize;
    for &(seq, d, mask) in &[
        (64usize, 32usize, MaskKind::None),
        (64, 32, MaskKind::Causal),
        (96, 32, MaskKind::Causal),
        (40, 16, MaskKind::None), // ragged seq, padded head dim
        (64, 32, MaskKind::PaddingKeys { valid: 40 }),
    ] {
        let req = gqa_req(1000 + checked as u64, 1, seq, d, heads, kv).with_mask(mask);
        let got: AttentionResponse = sim.submit_wait(req.clone()).unwrap();
        let want = reference.submit_wait(req).unwrap();
        assert_eq!(
            bits(&got.output.expect("sim serving succeeds")),
            bits(&want.output.expect("reference serving succeeds")),
            "seq={seq} d={d} {mask:?}: sim pool diverged from reference pool"
        );
        // Every shard was priced from measured machine cycles…
        assert_eq!(got.stats.measured_shards, got.shards, "seq={seq} {mask:?}");
        assert_eq!(want.stats.measured_shards, 0, "reference pool models, never measures");
        // …the sim pool attributes every one of those cycles to an
        // instruction class — the breakdown sums EXACTLY to the priced
        // total (DESIGN.md §9) — while the model-priced reference pool
        // carries no breakdown…
        let bd = got.stats.cycle_breakdown.expect("sim responses carry attribution");
        assert_eq!(
            bd.total(),
            got.device_cycles,
            "seq={seq} {mask:?}: attribution must sum to the priced cycles ({bd:?})"
        );
        assert!(bd.score > 0 && bd.exp > 0 && bd.rowsum > 0, "seq={seq} {mask:?}: {bd:?}");
        assert_eq!(bd.recompute, 0, "stateless serving never recomputes");
        match mask {
            MaskKind::None => assert_eq!(bd.mask_wave, 0, "unmasked shards ride no mask wave"),
            _ => assert!(bd.mask_wave > 0, "seq={seq} {mask:?}: masked intervals must be counted"),
        }
        assert!(want.stats.cycle_breakdown.is_none(), "modeled cycles have no measured attribution");
        // …and measured disagrees with the model by less than the band
        // while not being the model (it is a genuine measurement).
        let accel = {
            let mut a = AccelConfig::builtin("fsa").unwrap();
            a.array_size = N;
            a
        };
        let modeled = multi_head_perf_masked(
            &accel, seq, d.min(N), heads, kv, 1, Variant::DualPath, accel.pwl_segments, mask,
        );
        // Whole-operator cost: heads × per-head cycles (cost metric, not
        // critical path — both pools sum shard cycles the same way).
        let ratio = got.device_cycles as f64 / modeled.total_cycles as f64;
        assert!(
            ratio >= SIM_MODEL_BAND.0 && ratio <= SIM_MODEL_BAND.1,
            "seq={seq} {mask:?}: measured {} vs modeled {} (ratio {ratio:.3})",
            got.device_cycles,
            modeled.total_cycles
        );
        checked += 1;
    }
    assert!(checked >= 3, "acceptance needs at least 3 shapes");

    // Dispatch metrics: every sim shard counted under `sim`, none under
    // `reference`/`pjrt` (and vice versa on the reference pool).
    let o = std::sync::atomic::Ordering::Relaxed;
    assert_eq!(
        sim.metrics.sim_dispatches.load(o),
        sim.metrics.head_shards.load(o)
    );
    assert_eq!(sim.metrics.reference_dispatches.load(o), 0);
    assert_eq!(sim.metrics.pjrt_dispatches.load(o), 0);
    assert_eq!(
        reference.metrics.reference_dispatches.load(o),
        reference.metrics.head_shards.load(o)
    );
    assert_eq!(reference.metrics.sim_dispatches.load(o), 0);
    assert!(sim.metrics.summary().contains("dispatch sim/ref/pjrt"));

    sim.shutdown();
    reference.shutdown();
}

/// Acceptance: causal prefill → decode steps through sessions + paged
/// KV caches on the sim pool, bitwise the reference pool step for step.
#[test]
fn sim_decode_session_is_bitwise_the_reference_pool() {
    let (seq, d, heads, kv, steps) = (64usize, 32usize, 2usize, 1usize, 3usize);
    let sim = Coordinator::start(cfg(BackendKind::Sim, 2, 1)).unwrap();
    let reference = Coordinator::start(cfg(BackendKind::Reference, 2, 1)).unwrap();

    let run = |coord: &Coordinator| -> Vec<Vec<f32>> {
        let mut rng = SplitMix64::new(4242);
        let mut outs = Vec::new();
        let prefill = AttentionRequest::prefill(
            1,
            7,
            seq,
            d,
            heads,
            kv,
            rng.normal_matrix(heads * seq, d),
            rng.normal_matrix(kv * seq, d),
            rng.normal_matrix(kv * seq, d),
        )
        .with_mask(MaskKind::Causal);
        let resp = coord.submit_wait(prefill).unwrap();
        outs.push(resp.output.expect("prefill succeeds"));
        for step in 0..steps as u64 {
            let dec = AttentionRequest::decode(
                2 + step,
                7,
                step,
                d,
                heads,
                kv,
                rng.normal_matrix(heads, d),
                rng.normal_matrix(kv, d),
                rng.normal_matrix(kv, d),
            );
            let resp = coord.submit_wait(dec).unwrap();
            // Decode responses on the sim pool attribute exactly too;
            // any recompute fallback is charged to its own class so the
            // sum still equals the priced cycles (measured + recompute).
            if resp.stats.measured_shards == resp.shards && resp.shards > 0 {
                let bd = resp.stats.cycle_breakdown.expect("measured decode carries attribution");
                assert_eq!(bd.total(), resp.device_cycles, "step {step}: {bd:?}");
            }
            outs.push(resp.output.expect("decode step succeeds"));
        }
        coord.submit_wait(AttentionRequest::close(99, 7)).unwrap();
        outs
    };

    let got = run(&sim);
    let want = run(&reference);
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(bits(g), bits(w), "stage {i} (0 = prefill) diverged");
    }
    let o = std::sync::atomic::Ordering::Relaxed;
    assert_eq!(sim.metrics.decode_steps.load(o), steps);
    assert!(sim.metrics.kv_hits.load(o) > 0, "decode must use the page caches");
    sim.shutdown();
    reference.shutdown();
}

/// PR 10 e2e: the compiled-program cache is a host-time optimization
/// only.  A sim pool serving prefill → decode with the cache on (the
/// default) is bitwise the same pool with the cache off and machine
/// reuse disabled — and the metrics prove the cache worked: hits
/// observed, programs built strictly fewer than shards executed, and
/// fewer machine allocations than the reuse-off twin.
#[test]
fn sim_prog_cache_serving_is_bitwise_cache_off_and_skips_rebuilds() {
    let (seq, d, heads, kv, steps) = (64usize, 32usize, 2usize, 1usize, 4usize);
    // One device so every shard flows through a single worker's cache
    // (per-worker caches never share entries across devices).
    let hot = Coordinator::start(cfg(BackendKind::Sim, 1, 1)).unwrap();
    let mut off = cfg(BackendKind::Sim, 1, 1);
    off.sim_prog_cache = 0;
    off.sim_batch_shards = 1;
    let cold = Coordinator::start(off).unwrap();

    let run = |coord: &Coordinator| -> Vec<Vec<f32>> {
        let mut rng = SplitMix64::new(1010);
        let mut outs = Vec::new();
        let prefill = AttentionRequest::prefill(
            1,
            11,
            seq,
            d,
            heads,
            kv,
            rng.normal_matrix(heads * seq, d),
            rng.normal_matrix(kv * seq, d),
            rng.normal_matrix(kv * seq, d),
        )
        .with_mask(MaskKind::Causal);
        outs.push(coord.submit_wait(prefill).unwrap().output.expect("prefill succeeds"));
        for step in 0..steps as u64 {
            let dec = AttentionRequest::decode(
                2 + step,
                11,
                step,
                d,
                heads,
                kv,
                rng.normal_matrix(heads, d),
                rng.normal_matrix(kv, d),
                rng.normal_matrix(kv, d),
            );
            outs.push(coord.submit_wait(dec).unwrap().output.expect("decode step succeeds"));
        }
        coord.submit_wait(AttentionRequest::close(99, 11)).unwrap();
        outs
    };

    let got = run(&hot);
    let want = run(&cold);
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(bits(g), bits(w), "stage {i} (0 = prefill): cache-on diverged from cache-off");
    }

    let o = std::sync::atomic::Ordering::Relaxed;
    let hits = hot.metrics.prog_cache_hits.load(o);
    let built = hot.metrics.prog_cache_misses.load(o);
    let shards = hot.metrics.sim_dispatches.load(o);
    assert!(hits > 0, "same-shape head shards must hit the cache");
    assert!(
        built < shards,
        "cache on: programs built ({built}) must be fewer than shards executed ({shards})"
    );
    // The cache-off twin builds on every lookup and never hits.
    assert_eq!(cold.metrics.prog_cache_hits.load(o), 0, "cache off must never hit");
    assert!(cold.metrics.prog_cache_misses.load(o) >= built, "cache off rebuilds everywhere");
    // Machine pooling: grow-on-demand reuse allocates strictly fewer
    // machines than the reuse-off (`sim_batch_shards = 1`) twin.
    assert!(
        hot.metrics.machines_allocated.load(o) < cold.metrics.machines_allocated.load(o),
        "pooled worker must allocate fewer machines ({} vs {})",
        hot.metrics.machines_allocated.load(o),
        cold.metrics.machines_allocated.load(o)
    );

    hot.shutdown();
    cold.shutdown();
}

/// Acceptance: `seq_shards = 2` chunked serving on the sim pool —
/// partial (O~, m, l) states computed on the array, merged in chunk
/// order at gather — bitwise the reference pool.
#[test]
fn sim_seqpar_serving_is_bitwise_the_reference_pool() {
    let (seq, d, heads, kv) = (64usize, 32usize, 4usize, 2usize);
    let sim = Coordinator::start(cfg(BackendKind::Sim, 3, 2)).unwrap();
    let reference = Coordinator::start(cfg(BackendKind::Reference, 3, 2)).unwrap();
    for (i, mask) in [MaskKind::None, MaskKind::Causal].into_iter().enumerate() {
        let req = gqa_req(7000 + i as u64, 1, seq, d, heads, kv).with_mask(mask);
        let got = sim.submit_wait(req.clone()).unwrap();
        let want = reference.submit_wait(req).unwrap();
        assert_eq!(got.stats.seq_chunks, 2, "{mask:?}");
        assert_eq!(got.shards, heads * 2, "{mask:?}");
        assert_eq!(
            bits(&got.output.expect("sim seqpar succeeds")),
            bits(&want.output.expect("reference seqpar succeeds")),
            "{mask:?}: chunked sim serving diverged"
        );
        assert_eq!(got.stats.measured_shards, got.shards, "{mask:?}");
        assert_eq!(got.stats.merge_steps, want.stats.merge_steps, "{mask:?}");
        // Chunked shards roll their per-shard breakdowns up at gather;
        // the exact-sum contract holds across the whole (head, chunk)
        // grid, not just single shards.
        let bd = got.stats.cycle_breakdown.expect("chunked sim responses carry attribution");
        assert_eq!(bd.total(), got.device_cycles, "{mask:?}: {bd:?}");
    }
    let o = std::sync::atomic::Ordering::Relaxed;
    assert!(sim.metrics.seq_chunk_shards.load(o) >= heads * 2);
    sim.shutdown();
    reference.shutdown();
}

/// Satellite e2e: the O(L²) guard — an over-long request on the sim
/// pool is rejected at admission with an error naming `sim_max_seq`,
/// and the same request is served after raising the knob's headroom on
/// a reference pool.
#[test]
fn sim_max_seq_guard_rejects_long_requests() {
    let sim = Coordinator::start(cfg(BackendKind::Sim, 1, 1)).unwrap();
    let (seq, d) = (512usize, 32usize); // > sim_max_seq = 256
    let req = gqa_req(9, 1, seq, d, 1, 1);
    let resp = sim.submit_wait(req.clone()).unwrap();
    let err = resp.output.unwrap_err();
    assert!(
        err.contains("sim_max_seq") && err.contains("512"),
        "guard error must name the knob: {err}"
    );
    assert_eq!(resp.shards, 0, "rejected before sharding");
    sim.shutdown();

    let reference = Coordinator::start(cfg(BackendKind::Reference, 1, 1)).unwrap();
    assert!(reference.submit_wait(req).unwrap().output.is_ok());
    reference.shutdown();
}
