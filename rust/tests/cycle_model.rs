//! The load-bearing validation: the cycle-accurate FSA simulator must
//! (a) produce the same numbers as the flash_pwl reference (which the
//! Pallas kernel is also tested against, closing the cross-layer loop),
//! and (b) reproduce the paper's §3.5 cycle counts — 5N+10 per inner
//! iteration in steady state (6N+10 for the single-path variant §8.2),
//! validating that the SystolicAttention schedule is hazard-free (the
//! array panics on any port conflict).

use fsa::kernel::{flash_attention_program, FlashLayout, FlashParams};
use fsa::kernel::flash::detranspose_output;
use fsa::numerics::reference::{flash_forward, mat_error, Exp2, Mat, Precision};
use fsa::numerics::pwl::PwlExp2;
use fsa::numerics::SplitMix64;
use fsa::schedule::{fsa_total_cycles, rescale_latency, InnerSchedule, Variant};
use fsa::sim::{Machine, MachineConfig};

fn run_flash(n: usize, seq: usize, quantize: bool, seed: u64) -> (Vec<f32>, fsa::sim::RunStats, Mat, Mat, Mat) {
    let p = FlashParams {
        seq_len: seq,
        d: n,
        spad_elems: (6 * n * n) as u32,
        accum_elems: (n * n + n) as u32,
    };
    let layout = FlashLayout::packed(&p);
    let prog = flash_attention_program(&p, &layout).unwrap();

    let mut cfg = MachineConfig::small(n);
    cfg.quantize = quantize;
    cfg.mem_elems = layout.mem_elems(&p).max(1 << 16);
    cfg.spad_elems = p.spad_elems as usize;
    cfg.accum_elems = p.accum_elems as usize;
    let mut m = Machine::new(cfg);

    let mut rng = SplitMix64::new(seed);
    let q = Mat::new(seq, n, rng.normal_matrix(seq, n));
    let k = Mat::new(seq, n, rng.normal_matrix(seq, n));
    let v = Mat::new(seq, n, rng.normal_matrix(seq, n));
    m.write_mem(layout.q_addr, &q.data);
    m.write_mem(layout.k_addr, &k.data);
    m.write_mem(layout.v_addr, &v.data);

    let stats = m.run_program(&prog).unwrap();
    let out = detranspose_output(m.read_mem(0, layout.mem_elems(&p)), &layout, &p);
    (out, stats, q, k, v)
}

#[test]
fn machine_matches_flash_pwl_reference_f32() {
    for (n, seq) in [(8usize, 16usize), (8, 32), (16, 32)] {
        let (out, stats, q, k, v) = run_flash(n, seq, false, 42 + n as u64);
        let want = flash_forward(
            &q, &k, &v, n, n,
            &Exp2::Pwl(PwlExp2::new(8)),
            Precision::F32,
        );
        let got = Mat::new(seq, n, out);
        let err = mat_error(&got, &want);
        assert!(
            err.max_abs < 2e-5,
            "n={n} seq={seq}: {err:?} (cycle sim diverged from flash_pwl oracle)"
        );
        assert!(stats.matmul_macs > 0);
    }
}

#[test]
fn machine_matches_flash_pwl_reference_f16() {
    let n = 16;
    let seq = 48;
    let (out, _, q, k, v) = run_flash(n, seq, true, 7);
    // fp16-quantized activations: reference quantizes identically.
    let want = flash_forward(
        &q, &k, &v, n, n,
        &Exp2::Pwl(PwlExp2::new(8)),
        Precision::F16F32,
    );
    let got = Mat::new(seq, n, out);
    let err = mat_error(&got, &want);
    // The sim and the host reference implement the same fp16 datapath
    // independently; agreement is expected to 1-2 fp16 ulps of the
    // O(0.1..1) outputs (rounding-order differences in the elementwise
    // chain), i.e. a few e-4 absolute.
    assert!(err.max_abs < 5e-4, "{err:?}");
    assert!(err.mae < 1e-4, "{err:?}");
}

#[test]
fn machine_close_to_dense_attention() {
    // End-to-end sanity against the *exact* oracle: within the paper's
    // Table-2-scale error budget.
    let n = 16;
    let seq = 64;
    let (out, _, q, k, v) = run_flash(n, seq, true, 99);
    let dense = fsa::numerics::reference::sdpa(&q, &k, &v);
    let err = mat_error(&Mat::new(seq, n, out), &dense);
    assert!(err.mae < 1e-2, "{err:?}");
    assert!(err.max_abs < 1e-1, "{err:?}");
}

#[test]
fn steady_state_iteration_matches_5n_plus_10() {
    // Measure the issue-to-issue interval by comparing two workloads that
    // differ by exactly one inner iteration (same outer structure).
    for n in [8usize, 16, 32] {
        let (_, s2, ..) = run_flash(n, 2 * n, false, 1);
        let (_, s3, ..) = run_flash(n, 3 * n, false, 1);
        // seq 2n -> t_r = 2 row blocks of t_c = 2 iterations; seq 3n ->
        // 3 x 3. Growth per added inner iteration must be 5N + 10.
        let sched = InnerSchedule::new(n, Variant::DualPath, 8);
        let ii = sched.inner_latency();
        assert_eq!(ii, 5 * n as u64 + 10);
        // Analytical totals from the schedule module:
        let a2 = fsa_total_cycles(2 * n, n, Variant::DualPath, 8);
        let a3 = fsa_total_cycles(3 * n, n, Variant::DualPath, 8);
        // The machine adds DMA/store epilogue overhead; compute-phase
        // totals must match the closed form within the epilogue margin.
        let eps = 200 + 2 * n as u64; // final store + drain margin
        assert!(
            s2.cycles >= a2 && s2.cycles <= a2 + eps,
            "n={n}: sim {} vs formula {a2}",
            s2.cycles
        );
        assert!(
            s3.cycles >= a3 && s3.cycles <= a3 + eps,
            "n={n}: sim {} vs formula {a3}",
            s3.cycles
        );
        // Per-iteration growth: (cycles3 - cycles2) covers 9-4=5 inner
        // iterations + one extra rescale.
        let growth = s3.cycles - s2.cycles;
        let want = 5 * ii + rescale_latency(n);
        assert!(
            growth >= want && growth <= want + eps,
            "n={n}: growth {growth} vs {want}"
        );
    }
}

#[test]
fn schedule_is_hazard_free_at_many_sizes() {
    // The array panics on any structural hazard; surviving a run IS the
    // assertion.  Cover several N including non-trivial multi-block seqs.
    for (n, seq) in [(4usize, 16usize), (8, 24), (32, 64)] {
        let (_, stats, ..) = run_flash(n, seq, true, n as u64);
        // Useful MACs: 2 matmuls x N^3 per inner iteration x t_r x t_c.
        let t = seq / n;
        assert_eq!(stats.matmul_macs as usize, 2 * n * n * n * t * t);
    }
}

#[test]
fn utilization_approaches_asymptote_with_seq_len() {
    let n = 16;
    let (_, s_short, ..) = run_flash(n, n, false, 3);
    let (_, s_long, ..) = run_flash(n, 8 * n, false, 3);
    let u_short = s_short.utilization(n);
    let u_long = s_long.utilization(n);
    assert!(u_long > u_short, "longer seq must amortize overheads");
    let ceiling = 2.0 * n as f64 / (5.0 * n as f64 + 10.0);
    assert!(u_long < ceiling);
    assert!(u_long > 0.75 * ceiling, "u={u_long} ceiling={ceiling}");
}
