//! Differential pin for the vectorized simulator (DESIGN.md §8's SoA
//! waves): the struct-of-arrays array keeps a frozen per-lane port of
//! the pre-refactor control flow (`MachineConfig::scalar_reference`),
//! and this harness drives randomized workloads through both paths,
//! asserting
//!
//! * outputs bitwise-equal to each other *and* to the reference twins
//!   (`flash_pwl_masked` / `flash_pwl_partial` / `decode_pwl{,_partial}`),
//! * measured cycle counts identical (the vectorization must not move a
//!   single edge event),
//! * every structural-hazard panic fires with the same message — and,
//!   since the messages embed `cycle {}`, at the same cycle — in both
//!   paths.
//!
//! The sweep is seeded (SplitMix64), so a failure names a reproducible
//! (n, L, d, mask, mode) tuple in its assert message.

use fsa::config::AccelConfig;
use fsa::kernel::flash::{flash_chunk_program, ChunkLayout, ChunkParams};
use fsa::mask::MaskKind;
use fsa::numerics::reference::{
    decode_pwl, decode_pwl_partial, flash_pwl_masked, flash_pwl_partial, flash_pwl_resumed, Mat,
};
use fsa::numerics::SplitMix64;
use fsa::runtime::{ShardPlan, SimBackend};
use fsa::sim::array::{Array, DownMsg, LeftTag};
use fsa::sim::{Machine, MachineConfig};

const SEGMENTS: usize = 8;

fn accel(n: usize) -> AccelConfig {
    let mut cfg = AccelConfig::builtin("fsa").unwrap();
    cfg.array_size = n;
    cfg
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// ~200 randomized cases over array size x sequence length x head dim x
/// mask x execution mode, biased toward the small arrays where a skew
/// bug has the fewest cycles to hide in.  Every case runs on a
/// vectorized backend and a scalar-reference backend — both through the
/// single typed entry point (`execute(ShardPlan)`, DESIGN.md §11) — and
/// must agree bitwise (outputs) and exactly (measured cycles) — and the
/// vectorized output must equal the analytic reference twin, so the
/// pair can't drift together.
#[test]
fn randomized_differential_sweep_is_bitwise_and_cycle_exact() {
    let mut rng = SplitMix64::new(0xD1FF);
    let mut cases = 0usize;
    for &(n, trials) in &[(8usize, 90usize), (16, 70), (32, 40)] {
        let mut vec_be = SimBackend::new(&accel(n));
        let mut sca_be = SimBackend::new(&accel(n));
        sca_be.set_scalar_reference(true);
        for trial in 0..trials {
            let l = 1 + rng.next_below(3 * n as u64) as usize;
            let d = [n / 4, n / 2, n][rng.next_below(3) as usize].max(1);
            let mask = match rng.next_below(3) {
                0 => MaskKind::None,
                1 => MaskKind::Causal,
                // valid >= 1 keeps every query row live; the fully-masked
                // operator short-circuit has its own test in sim_backend.rs.
                _ => MaskKind::PaddingKeys { valid: 1 + rng.next_below(l as u64) as usize },
            };
            let mode = rng.next_below(5);
            let ctx = format!("n={n} L={l} d={d} {mask:?} mode={mode} trial={trial}");
            match mode {
                0 => {
                    // Whole head.
                    let q = rng.normal_matrix(l, d);
                    let k = rng.normal_matrix(l, d);
                    let v = rng.normal_matrix(l, d);
                    let plan = || ShardPlan::Head { seq_len: l, d, q: &q, k: &k, v: &v, mask };
                    let got = vec_be.execute(plan()).unwrap().into_full().unwrap();
                    let twin = sca_be.execute(plan()).unwrap().into_full().unwrap();
                    assert_eq!(bits(&got), bits(&twin), "vec vs scalar: {ctx}");
                    let want = flash_pwl_masked(
                        &Mat::new(l, d, q.clone()),
                        &Mat::new(l, d, k.clone()),
                        &Mat::new(l, d, v.clone()),
                        n,
                        n,
                        SEGMENTS,
                        mask,
                    );
                    assert_eq!(bits(&got), bits(&want.data), "vec vs reference: {ctx}");
                }
                1 => {
                    // Sequence-parallel chunk at global key coordinates.
                    let start = rng.next_below(l as u64) as usize;
                    let len = 1 + rng.next_below((l - start) as u64) as usize;
                    let q = rng.normal_matrix(l, d);
                    let kc = rng.normal_matrix(len, d);
                    let vc = rng.normal_matrix(len, d);
                    let plan = || ShardPlan::HeadChunk {
                        seq_len: l,
                        d,
                        q: &q,
                        k_chunk: &kc,
                        v_chunk: &vc,
                        mask,
                        key_offset: start,
                        total_keys: l,
                    };
                    let got = vec_be.execute(plan()).unwrap().into_partial().unwrap();
                    let twin = sca_be.execute(plan()).unwrap().into_partial().unwrap();
                    assert_eq!(got, twin, "vec vs scalar: {ctx} chunk [{start}, {})", start + len);
                    let want = flash_pwl_partial(
                        &Mat::new(l, d, q),
                        &Mat::new(len, d, kc),
                        &Mat::new(len, d, vc),
                        n,
                        n,
                        SEGMENTS,
                        mask,
                        start,
                        l,
                    );
                    assert_eq!(got, want, "vec vs reference: {ctx} chunk [{start}, {})", start + len);
                }
                2 => {
                    // Decode row over an L-token prefix (mask-free path).
                    let qr = rng.normal_matrix(1, d);
                    let k = rng.normal_matrix(l, d);
                    let v = rng.normal_matrix(l, d);
                    let plan =
                        || ShardPlan::DecodeRow { prefix_len: l, d, q_row: &qr, k: &k, v: &v };
                    let got = vec_be.execute(plan()).unwrap().into_full().unwrap();
                    let twin = sca_be.execute(plan()).unwrap().into_full().unwrap();
                    assert_eq!(bits(&got), bits(&twin), "vec vs scalar: {ctx}");
                    let want = decode_pwl(&qr, &k, &v, d, n, SEGMENTS);
                    assert_eq!(bits(&got), bits(&want), "vec vs reference: {ctx}");
                }
                3 => {
                    // Split-KV decode range (partial state out).
                    let qr = rng.normal_matrix(1, d);
                    let k = rng.normal_matrix(l, d);
                    let v = rng.normal_matrix(l, d);
                    let plan =
                        || ShardPlan::DecodeRange { range_len: l, d, q_row: &qr, k: &k, v: &v };
                    let got = vec_be.execute(plan()).unwrap().into_partial().unwrap();
                    let twin = sca_be.execute(plan()).unwrap().into_partial().unwrap();
                    assert_eq!(got, twin, "vec vs scalar: {ctx}");
                    let want = decode_pwl_partial(&qr, &k, &v, d, n, SEGMENTS);
                    assert_eq!(got, want, "vec vs reference: {ctx}");
                }
                _ => {
                    // Resumed (prefix-warm) whole-range prefill: suffix
                    // rows at global mask coordinates (DESIGN.md §11).
                    let resume = rng.next_below(l as u64) as usize;
                    let rows = l - resume;
                    let q = rng.normal_matrix(rows, d);
                    let k = rng.normal_matrix(l, d);
                    let v = rng.normal_matrix(l, d);
                    let plan = || ShardPlan::ResumedPrefill {
                        seq_len: l,
                        d,
                        query_offset: resume,
                        q_suffix: &q,
                        k_chunk: &k,
                        v_chunk: &v,
                        mask,
                        key_offset: 0,
                        total_keys: l,
                    };
                    let got = vec_be.execute(plan()).unwrap().into_full().unwrap();
                    let twin = sca_be.execute(plan()).unwrap().into_full().unwrap();
                    assert_eq!(bits(&got), bits(&twin), "vec vs scalar: {ctx} resume {resume}");
                    let want = flash_pwl_resumed(
                        &Mat::new(rows, d, q),
                        &Mat::new(l, d, k),
                        &Mat::new(l, d, v),
                        n,
                        n,
                        SEGMENTS,
                        mask,
                        resume,
                        0,
                        l,
                    )
                    .finalize();
                    assert_eq!(
                        bits(&got),
                        bits(&want.data),
                        "vec vs reference: {ctx} resume {resume}"
                    );
                }
            }
            // The vectorization must not move a single cycle.
            let vc = vec_be.take_measured().expect("sim runs measure");
            let sc = sca_be.take_measured().expect("sim runs measure");
            assert_eq!(vc, sc, "measured cycles: {ctx}");
            assert!(vc > 0, "live case must cost cycles: {ctx}");
            cases += 1;
        }
    }
    assert_eq!(cases, 200);
}

/// Hot-path contract (DESIGN.md §12): the compiled-program cache and
/// the persistent machine pool may only spend or save *host* time.
/// Over the same randomized grid as the main sweep, a backend with
/// both enabled (the serving defaults) must produce bitwise-identical
/// outputs, identical measured cycles, and an identical per-class
/// `CycleBreakdown` against a twin with both disabled
/// (`sim_prog_cache = 0`, `sim_batch_shards = 1`) — while the cached
/// side actually exercises the cache (hits observed, fewer programs
/// built than looked up).
#[test]
fn prog_cache_and_machine_pool_sweep_is_bitwise_and_cycle_exact() {
    let mut rng = SplitMix64::new(0xCAC4E);
    let mut cases = 0usize;
    let mut hits = 0u64;
    let mut lookups = 0u64;
    for &(n, trials) in &[(8usize, 90usize), (16, 70), (32, 40)] {
        // `hot` keeps the defaults: program cache on, machine reuse on.
        let mut hot = SimBackend::new(&accel(n));
        let mut cold = SimBackend::new(&accel(n));
        cold.set_prog_cache(0);
        cold.set_batch_shards(1);
        for trial in 0..trials {
            let l = 1 + rng.next_below(3 * n as u64) as usize;
            let d = [n / 4, n / 2, n][rng.next_below(3) as usize].max(1);
            let mask = match rng.next_below(3) {
                0 => MaskKind::None,
                1 => MaskKind::Causal,
                _ => MaskKind::PaddingKeys { valid: 1 + rng.next_below(l as u64) as usize },
            };
            let mode = rng.next_below(5);
            let ctx = format!("n={n} L={l} d={d} {mask:?} mode={mode} trial={trial}");
            match mode {
                0 => {
                    let q = rng.normal_matrix(l, d);
                    let k = rng.normal_matrix(l, d);
                    let v = rng.normal_matrix(l, d);
                    let plan = || ShardPlan::Head { seq_len: l, d, q: &q, k: &k, v: &v, mask };
                    let got = hot.execute(plan()).unwrap().into_full().unwrap();
                    let want = cold.execute(plan()).unwrap().into_full().unwrap();
                    assert_eq!(bits(&got), bits(&want), "hot vs cold: {ctx}");
                }
                1 => {
                    let start = rng.next_below(l as u64) as usize;
                    let len = 1 + rng.next_below((l - start) as u64) as usize;
                    let q = rng.normal_matrix(l, d);
                    let kc = rng.normal_matrix(len, d);
                    let vc = rng.normal_matrix(len, d);
                    let plan = || ShardPlan::HeadChunk {
                        seq_len: l,
                        d,
                        q: &q,
                        k_chunk: &kc,
                        v_chunk: &vc,
                        mask,
                        key_offset: start,
                        total_keys: l,
                    };
                    let got = hot.execute(plan()).unwrap().into_partial().unwrap();
                    let want = cold.execute(plan()).unwrap().into_partial().unwrap();
                    assert_eq!(got, want, "hot vs cold: {ctx} chunk [{start}, {})", start + len);
                }
                2 => {
                    let qr = rng.normal_matrix(1, d);
                    let k = rng.normal_matrix(l, d);
                    let v = rng.normal_matrix(l, d);
                    let plan =
                        || ShardPlan::DecodeRow { prefix_len: l, d, q_row: &qr, k: &k, v: &v };
                    let got = hot.execute(plan()).unwrap().into_full().unwrap();
                    let want = cold.execute(plan()).unwrap().into_full().unwrap();
                    assert_eq!(bits(&got), bits(&want), "hot vs cold: {ctx}");
                }
                3 => {
                    let qr = rng.normal_matrix(1, d);
                    let k = rng.normal_matrix(l, d);
                    let v = rng.normal_matrix(l, d);
                    let plan =
                        || ShardPlan::DecodeRange { range_len: l, d, q_row: &qr, k: &k, v: &v };
                    let got = hot.execute(plan()).unwrap().into_partial().unwrap();
                    let want = cold.execute(plan()).unwrap().into_partial().unwrap();
                    assert_eq!(got, want, "hot vs cold: {ctx}");
                }
                _ => {
                    let resume = rng.next_below(l as u64) as usize;
                    let rows = l - resume;
                    let q = rng.normal_matrix(rows, d);
                    let k = rng.normal_matrix(l, d);
                    let v = rng.normal_matrix(l, d);
                    let plan = || ShardPlan::ResumedPrefill {
                        seq_len: l,
                        d,
                        query_offset: resume,
                        q_suffix: &q,
                        k_chunk: &k,
                        v_chunk: &v,
                        mask,
                        key_offset: 0,
                        total_keys: l,
                    };
                    let got = hot.execute(plan()).unwrap().into_full().unwrap();
                    let want = cold.execute(plan()).unwrap().into_full().unwrap();
                    assert_eq!(bits(&got), bits(&want), "hot vs cold: {ctx} resume {resume}");
                }
            }
            // Neither the cache nor machine reuse may move a cycle —
            // or shift a single cycle between attribution classes.
            let hc = hot.take_measured().expect("sim runs measure");
            let cc = cold.take_measured().expect("sim runs measure");
            assert_eq!(hc, cc, "measured cycles: {ctx}");
            let hb = hot.take_measured_breakdown().expect("sim runs attribute");
            let cb = cold.take_measured_breakdown().expect("sim runs attribute");
            assert_eq!(hb, cb, "cycle breakdown: {ctx}");
            assert_eq!(hb.total(), hc, "breakdown must sum to cycles: {ctx}");
            cases += 1;
        }
        let hp = hot.take_hotpath_stats();
        hits += hp.prog_cache_hits;
        lookups += hp.prog_cache_hits + hp.prog_cache_misses;
        let cp = cold.take_hotpath_stats();
        assert_eq!(cp.prog_cache_hits, 0, "n={n}: cache-off twin must never hit");
        assert!(cp.prog_cache_misses > 0, "n={n}: cache-off twin counts every build");
    }
    assert_eq!(cases, 200);
    assert!(hits > 0, "the randomized grid must revisit at least one program shape");
    assert!(
        lookups - hits < lookups,
        "programs built ({}) must be fewer than program lookups ({lookups})",
        lookups - hits
    );
}

/// Full `RunStats` equality at machine level: every counter the stats
/// report — not just cycles — is identical between the two step paths,
/// and so is the final memory image, bit for bit.
#[test]
fn run_stats_are_identical_between_vectorized_and_scalar_paths() {
    let n = 32;
    for &(l, mask) in &[
        (96usize, MaskKind::Causal),
        (64, MaskKind::None),
        (40, MaskKind::PaddingKeys { valid: 25 }),
    ] {
        let p = ChunkParams::whole(n, l, mask);
        let layout = ChunkLayout::packed(&p);
        let prog = flash_chunk_program(&p, &layout).unwrap();
        let mut rng = SplitMix64::new(0xBEEF ^ l as u64);
        let data = rng.normal_matrix(p.padded_queries(), n);
        let run = |scalar: bool| {
            let mut mc = MachineConfig::from_accel(&accel(n));
            mc.scalar_reference = scalar;
            mc.mem_elems = layout.mem_elems(&p).max(1 << 12);
            let mut m = Machine::new(mc);
            m.write_mem(layout.q_addr, &data);
            m.write_mem(layout.k_addr, &data);
            m.write_mem(layout.v_addr, &data);
            let stats = m.run_program(&prog).unwrap();
            let image = bits(m.read_mem(0, layout.mem_elems(&p)));
            (stats, image)
        };
        let (sv, iv) = run(false);
        let (ss, is) = run(true);
        assert_eq!(sv.cycles, ss.cycles, "L={l} {mask:?}");
        // Cycle attribution (DESIGN.md §9) is part of the stats
        // contract: both steppers must charge identical per-class
        // counts, and the classes must sum exactly to the total.
        assert_eq!(sv.breakdown, ss.breakdown, "L={l} {mask:?}");
        assert_eq!(sv.breakdown.total(), sv.cycles, "L={l} {mask:?}: {:?}", sv.breakdown);
        assert_eq!(sv.matmul_macs, ss.matmul_macs, "L={l} {mask:?}");
        assert_eq!(sv.total_pe_ops, ss.total_pe_ops, "L={l} {mask:?}");
        assert_eq!(sv.dma_load_busy, ss.dma_load_busy, "L={l} {mask:?}");
        assert_eq!(sv.dma_store_busy, ss.dma_store_busy, "L={l} {mask:?}");
        assert_eq!(sv.compute_busy, ss.compute_busy, "L={l} {mask:?}");
        assert_eq!(sv.instructions, ss.instructions, "L={l} {mask:?}");
        assert_eq!(iv, is, "memory image L={l} {mask:?}");
    }
}

/// Run `f` expecting a panic; return the panic payload as a string with
/// the default hook silenced (so expected panics don't spam the test
/// log with backtraces).
fn panic_message<F: FnOnce() + std::panic::UnwindSafe>(f: F) -> String {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let res = std::panic::catch_unwind(f);
    std::panic::set_hook(prev);
    let err = res.expect_err("scenario must panic");
    if let Some(s) = err.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = err.downcast_ref::<&str>() {
        (*s).into()
    } else {
        "<non-string panic payload>".into()
    }
}

/// Structural-hazard regression: every hazard panic the array can raise
/// fires with an identical message — including the embedded cycle
/// number — in the vectorized and the scalar-reference paths.  A
/// vectorization that reordered wave phases would move or reword one of
/// these before it could corrupt data silently.
#[test]
fn hazard_panics_fire_identically_in_both_step_paths() {
    type Setup = fn(&mut Array);
    let scenarios: &[(&str, Setup)] = &[
        ("orphan-psum", |a| a.inject_left(1, 1.0, LeftTag::MacUp)),
        ("park-falloff", |a| {
            a.inject_top(0, DownMsg::Park { val: 2.0, hops: 7, masked: false })
        }),
        ("preload-falloff", |a| a.inject_top(1, DownMsg::Preload { val: 2.0, hops: 9 })),
        ("unconsumed-rowsum", |a| a.inject_top(0, DownMsg::RowSum { val: 1.0 })),
        ("rowsum-meets-park", |a| {
            a.inject_left(0, 1.0, LeftTag::RowSum);
            a.inject_top(0, DownMsg::Park { val: 2.0, hops: 3, masked: false });
        }),
        ("pv-meets-park", |a| {
            a.inject_left(0, 1.0, LeftTag::MacDown);
            a.inject_top(0, DownMsg::Park { val: 2.0, hops: 3, masked: false });
        }),
        ("pv-without-psum", |a| a.inject_left(1, 1.0, LeftTag::MacDown)),
        ("double-left-injection", |a| {
            a.inject_left(2, 1.0, LeftTag::MulConst);
            a.inject_left(2, 2.0, LeftTag::MulConst);
        }),
    ];
    for &(name, setup) in scenarios {
        let msg_of = |scalar: bool| {
            panic_message(move || {
                let mut a = Array::new(4, SEGMENTS, false);
                a.scalar_reference = scalar;
                setup(&mut a);
                for _ in 0..32 {
                    a.step();
                }
            })
        };
        let v = msg_of(false);
        let s = msg_of(true);
        assert_eq!(v, s, "hazard '{name}' diverged between step paths");
        assert!(
            v.contains("cycle"),
            "hazard '{name}' message must pin the firing cycle: {v}"
        );
    }
}

/// The decode-row hazard case of `sim_backend.rs`, parameterized over
/// both step paths: br = 1 program shapes (including prefixes straddling
/// tile boundaries) must survive the port-hazard asserts whichever
/// stepper runs them.
#[test]
fn decode_row_hazard_sweep_covers_both_step_paths() {
    let n = 16;
    for scalar in [false, true] {
        let mut be = SimBackend::new(&accel(n));
        if scalar {
            be.set_scalar_reference(true);
        }
        let mut rng = SplitMix64::new(0xDEC0);
        for prefix in [1usize, 15, 16, 17, 47] {
            let qr = rng.normal_matrix(1, n);
            let k = rng.normal_matrix(prefix, n);
            let v = rng.normal_matrix(prefix, n);
            // A panic here IS the failure; the finiteness check is a bonus.
            let out = be
                .execute(ShardPlan::DecodeRow { prefix_len: prefix, d: n, q_row: &qr, k: &k, v: &v })
                .unwrap()
                .into_full()
                .unwrap();
            assert!(out.iter().all(|x| x.is_finite()), "scalar={scalar} prefix={prefix}");
            assert!(be.take_measured().unwrap() > 0);
        }
    }
}
