//! End-to-end continuous-batching tests (DESIGN.md §10): the ISSUE-8
//! acceptance suite for the persistent queue + scheduler serving loop.
//!
//! The load-bearing invariant: a mixed workload — stateless operators
//! (plain, causal, key-padded) interleaved with prefill → N decode
//! steps → close sessions — served through the continuous scheduler
//! under *tight* token budgets (prefills deferred across waves, decode
//! steps of many sessions sharing dispatch waves) is **bitwise
//! identical per request** to the same workload served under
//! never-defer budgets, on the reference AND sim backends, whole
//! sequences and `seq_shards = 2`.  Continuous scheduling may change
//! only *when* work runs, never *what* it computes.
//!
//! Alongside the bits: scheduler metrics reconcile exactly
//! (`sched_admitted = sched_queued − sched_rejected` at quiescence), at
//! least one dispatched decode wave carries more than one session (the
//! continuous-batching payoff), and responses stream back per request
//! while later work is still unsubmitted.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc;

use fsa::config::{BackendKind, RunConfig};
use fsa::coordinator::request::{AttentionRequest, AttentionResponse};
use fsa::coordinator::Coordinator;
use fsa::mask::MaskKind;
use fsa::numerics::reference::decode_pwl;
use fsa::numerics::SplitMix64;

/// Mixed workload phases, submitted pipelined within each phase:
/// stateless ops, session prefills, per-round decode steps (one per
/// session per round — the shards that must share waves), closes.
struct Workload {
    stateless: Vec<AttentionRequest>,
    prefills: Vec<AttentionRequest>,
    rounds: Vec<Vec<AttentionRequest>>,
    closes: Vec<AttentionRequest>,
}

/// Deterministic workload: same seed → bitwise-identical requests, so
/// two coordinators can be fed the exact same bits.
#[allow(clippy::too_many_arguments)]
fn mixed_workload(
    seed: u64,
    sessions: &[u64],
    seq: usize,
    d: usize,
    heads: usize,
    kv: usize,
    steps: usize,
    with_masks: bool,
) -> Workload {
    let mut rng = SplitMix64::new(seed);
    let mut stateless = Vec::new();
    let mk_stateless = |rng: &mut SplitMix64, id: u64, mask: MaskKind| {
        let q = rng.normal_matrix(heads * seq, d);
        let k = rng.normal_matrix(kv * seq, d);
        let v = rng.normal_matrix(kv * seq, d);
        AttentionRequest::gqa(id, seq, d, heads, kv, q, k, v).with_mask(mask)
    };
    stateless.push(mk_stateless(&mut rng, 1, MaskKind::None));
    if with_masks {
        stateless.push(mk_stateless(&mut rng, 2, MaskKind::Causal));
        stateless.push(mk_stateless(
            &mut rng,
            3,
            MaskKind::PaddingKeys { valid: seq - seq / 4 },
        ));
    }
    let prefills = sessions
        .iter()
        .enumerate()
        .map(|(i, &s)| {
            let q = rng.normal_matrix(heads * seq, d);
            let k = rng.normal_matrix(kv * seq, d);
            let v = rng.normal_matrix(kv * seq, d);
            let req =
                AttentionRequest::prefill(100 + i as u64, s, seq, d, heads, kv, q, k, v);
            // One causal session rides along when masks are on (causal
            // prefill is the transformer case; its decode steps carry
            // no mask, the step row IS the causal row).
            if with_masks && i == 0 { req.with_mask(MaskKind::Causal) } else { req }
        })
        .collect();
    let rounds = (0..steps)
        .map(|r| {
            sessions
                .iter()
                .enumerate()
                .map(|(i, &s)| {
                    let q = rng.normal_matrix(heads, d);
                    let k = rng.normal_matrix(kv, d);
                    let v = rng.normal_matrix(kv, d);
                    AttentionRequest::decode(
                        1000 + (r as u64) * 100 + i as u64,
                        s,
                        r as u64,
                        d,
                        heads,
                        kv,
                        q,
                        k,
                        v,
                    )
                })
                .collect()
        })
        .collect();
    let closes = sessions
        .iter()
        .enumerate()
        .map(|(i, &s)| AttentionRequest::close(9000 + i as u64, s))
        .collect();
    Workload { stateless, prefills, rounds, closes }
}

/// Submit a phase pipelined (every request in flight at once), then
/// collect each request's streamed response into the output map.
fn submit_phase(
    coord: &Coordinator,
    reqs: Vec<AttentionRequest>,
    out: &mut BTreeMap<u64, Vec<f32>>,
) {
    let rxs: Vec<(u64, mpsc::Receiver<AttentionResponse>)> = reqs
        .into_iter()
        .map(|r| {
            let id = r.id;
            (id, coord.submit(r).unwrap())
        })
        .collect();
    for (id, rx) in rxs {
        let resp = rx.recv().unwrap();
        out.insert(id, resp.output.unwrap_or_else(|e| panic!("request {id}: {e}")));
    }
}

/// Serve the whole workload; returns every request's output bits plus
/// the completed-count observed after the FIRST decode round — the
/// streaming probe (it must be mid-run: > 0 and < the final total).
fn serve_workload(coord: &Coordinator, w: Workload) -> (BTreeMap<u64, Vec<f32>>, u64) {
    let mut out = BTreeMap::new();
    submit_phase(coord, w.stateless, &mut out);
    submit_phase(coord, w.prefills, &mut out);
    let mut mid_completed = 0u64;
    for (r, round) in w.rounds.into_iter().enumerate() {
        submit_phase(coord, round, &mut out);
        if r == 0 {
            mid_completed = coord.metrics.completed.load(Ordering::Relaxed) as u64;
        }
    }
    submit_phase(coord, w.closes, &mut out);
    (out, mid_completed)
}

/// Budgets that never defer: the one-shot baseline (admit-everything,
/// small batches, short timeout — the old `Batcher`'s behavior).
fn one_shot_cfg(backend: BackendKind, heads: usize, kv: usize) -> RunConfig {
    RunConfig {
        devices: 2,
        max_batch: 2,
        batch_timeout_cycles: 50_000,
        queue_depth: 256,
        backend,
        num_heads: heads,
        num_kv_heads: kv,
        max_batch_prefill_tokens: usize::MAX / 4,
        max_batch_total_tokens: usize::MAX / 2,
        waiting_served_ratio: 0.0,
        ..RunConfig::default()
    }
}

/// Tight continuous budgets: `max_batch_prefill_tokens` admits at most
/// two seq-32 prefills per wave (the third defers), the long group
/// timeout + `max_batch = 6` let all three sessions' decode shards
/// (2 each) assemble into shared waves.
fn continuous_cfg(backend: BackendKind, heads: usize, kv: usize, seq: usize) -> RunConfig {
    RunConfig {
        devices: 2,
        max_batch: 6,
        // ~3.3 ms at 1.5 GHz: long enough for one round's decode steps
        // of all sessions to join one wave, short enough to keep the
        // test fast.
        batch_timeout_cycles: 5_000_000,
        queue_depth: 256,
        backend,
        num_heads: heads,
        num_kv_heads: kv,
        max_batch_prefill_tokens: 2 * seq,
        max_batch_total_tokens: 64 * seq,
        waiting_served_ratio: 1.2,
        ..RunConfig::default()
    }
}

/// ISSUE-8 acceptance, reference backend: mixed workload through the
/// continuous scheduler is bitwise identical per request to the
/// never-defer baseline; scheduler metrics reconcile; at least one
/// decode wave spans > 1 session; responses stream before end-of-run.
#[test]
fn continuous_matches_one_shot_bitwise_on_reference() {
    let (seq, d, heads, kv, steps) = (32usize, 16usize, 2usize, 1usize, 8usize);
    let sessions = [7u64, 8, 9];

    let baseline = Coordinator::start(one_shot_cfg(BackendKind::Reference, heads, kv)).unwrap();
    let (want, _) = serve_workload(
        &baseline,
        mixed_workload(0xC0FFEE, &sessions, seq, d, heads, kv, steps, true),
    );
    baseline.shutdown();

    let coord =
        Coordinator::start(continuous_cfg(BackendKind::Reference, heads, kv, seq)).unwrap();
    let (got, mid_completed) = serve_workload(
        &coord,
        mixed_workload(0xC0FFEE, &sessions, seq, d, heads, kv, steps, true),
    );

    // Bitwise equivalence, request by request.
    assert_eq!(want.len(), got.len());
    for (id, bits) in &want {
        assert_eq!(
            got.get(id).unwrap(),
            bits,
            "request {id} diverged between continuous and one-shot scheduling"
        );
    }

    // Streaming: after round 0, the stateless + prefill + first-round
    // responses were already answered while 7 more rounds (and the
    // closes) had not been submitted.
    let o = Ordering::Relaxed;
    let total = coord.metrics.completed.load(o) as u64;
    assert!(mid_completed >= (3 + sessions.len() * 2) as u64, "{mid_completed}");
    assert!(mid_completed < total, "responses must stream before end-of-run");

    // A request over the prefill budget is rejected with an error
    // naming the knob (and feeds the reconciliation below).
    let m = vec![0.0f32; 3 * seq * d];
    let resp = coord
        .submit_wait(AttentionRequest::new(5000, 3 * seq, d, m.clone(), m.clone(), m))
        .unwrap();
    let err = resp.output.unwrap_err();
    assert!(err.contains("max_batch_prefill_tokens"), "{err}");

    // Reconciliation at quiescence: every envelope the scheduler queued
    // was either dispatched or answered inline (closes + the budget
    // reject), nothing lost.
    let queued = coord.metrics.sched_queued.load(o);
    let admitted = coord.metrics.sched_admitted.load(o);
    let rejected = coord.metrics.sched_rejected.load(o);
    assert_eq!(queued, coord.metrics.submitted.load(o) as u64);
    assert_eq!(admitted, queued - rejected, "admitted = queued - rejected");
    // Inline answers: 3 closes + 1 budget reject.
    assert_eq!(rejected, 4);

    // The continuous-batching payoff: decode waves exist, and at least
    // one dispatched wave carried decode shards of MORE than one
    // session (3 sessions × 2 shards assemble under the 6-shard batch
    // before the ~3.3 ms group timeout, across 8 rounds).
    assert!(coord.metrics.decode_waves.load(o) >= 1);
    assert!(
        coord.metrics.multi_session_decode_waves.load(o) >= 1,
        "no dispatch wave ever mixed decode shards of two sessions"
    );
    assert!(coord.metrics.prefill_waves.load(o) >= 1);
    assert!(coord.metrics.sched_iterations.load(o) >= 1);

    // Queue-depth histogram saw the per-iteration samples, not only
    // the per-admit ones (satellite: steady-state queueing).
    let snap = coord.metrics.snapshot();
    assert!(snap.queue_depth.count > admitted, "iteration samples missing");
    assert!(snap.batch_occupancy.count >= 1);
    coord.shutdown();
}

/// The same contract on the cycle-accurate sim backend (small shapes:
/// the sim is O(L²·N) per shard).
#[test]
fn continuous_matches_one_shot_bitwise_on_sim() {
    let (seq, d, heads, kv, steps) = (16usize, 8usize, 2usize, 1usize, 3usize);
    let sessions = [3u64, 4];

    let mut base = one_shot_cfg(BackendKind::Sim, heads, kv);
    base.array_size = 8;
    let baseline = Coordinator::start(base).unwrap();
    let (want, _) = serve_workload(
        &baseline,
        mixed_workload(0x51A, &sessions, seq, d, heads, kv, steps, true),
    );
    baseline.shutdown();

    let mut cont = continuous_cfg(BackendKind::Sim, heads, kv, seq);
    cont.array_size = 8;
    // Budget of one prefill per wave: the second session's prefill is
    // deferred a wave — scheduling moves, bits must not.
    cont.max_batch_prefill_tokens = seq;
    let coord = Coordinator::start(cont).unwrap();
    let (got, _) = serve_workload(
        &coord,
        mixed_workload(0x51A, &sessions, seq, d, heads, kv, steps, true),
    );
    assert_eq!(want, got, "sim bits diverged under continuous scheduling");

    let o = Ordering::Relaxed;
    assert_eq!(
        coord.metrics.sched_admitted.load(o),
        coord.metrics.sched_queued.load(o) - coord.metrics.sched_rejected.load(o)
    );
    assert!(coord.metrics.sim_dispatches.load(o) > 0, "must serve on the sim backend");
    coord.shutdown();
}

/// The same contract sequence-sharded: every request split into two
/// K/V chunks merged at gather (`seq_shards = 2`), continuous vs
/// one-shot — the partial-merge order is part of "what it computes"
/// and must survive rescheduling.
#[test]
fn continuous_matches_one_shot_bitwise_with_seq_shards() {
    let (seq, d, heads, kv, steps) = (32usize, 16usize, 2usize, 1usize, 3usize);
    let sessions = [11u64, 12];

    let mut base = one_shot_cfg(BackendKind::Reference, heads, kv);
    base.seq_shards = 2;
    let baseline = Coordinator::start(base).unwrap();
    let (want, _) = serve_workload(
        &baseline,
        mixed_workload(0xBEEF, &sessions, seq, d, heads, kv, steps, false),
    );
    baseline.shutdown();

    let mut cont = continuous_cfg(BackendKind::Reference, heads, kv, seq);
    cont.seq_shards = 2;
    cont.max_batch_prefill_tokens = seq; // one prefill per wave
    let coord = Coordinator::start(cont).unwrap();
    let (got, _) = serve_workload(
        &coord,
        mixed_workload(0xBEEF, &sessions, seq, d, heads, kv, steps, false),
    );
    assert_eq!(want, got, "seq-sharded bits diverged under continuous scheduling");
    let o = Ordering::Relaxed;
    assert!(coord.metrics.seqpar_requests.load(o) > 0);
    coord.shutdown();
}

/// Satellite (PR-2 incarnation regression, extended to the scheduler
/// loop): close + re-prefill + decode of a REUSED session id submitted
/// back-to-back — all three in the wait queue at once, resolved across
/// scheduler iterations — must serve the new incarnation's K/V, never
/// the dead predecessor's.  The wait queue's per-session ordering
/// invariant is what makes the pipelined sequence safe.
#[test]
fn reused_session_id_pipelined_through_scheduler_never_serves_stale_kv() {
    let (seq, d, heads) = (64usize, 16usize, 2usize);
    // Defaults: array 128, 8 PWL segments — the oracle must tile the
    // same way as the workers' reference backend.
    let (array, segments) = (128usize, 8usize);
    let mut cfg = continuous_cfg(BackendKind::Reference, heads, 1, seq);
    cfg.devices = 1; // deterministic placement: leftovers stay resident
    let coord = Coordinator::start(cfg).unwrap();
    let mut rng = SplitMix64::new(42);

    // First incarnation of id 5: prefill, one decode (so its pages are
    // cached), NO close yet.
    let q = rng.normal_matrix(heads * seq, d);
    let k = rng.normal_matrix(seq, d);
    let v = rng.normal_matrix(seq, d);
    let resp = coord
        .submit_wait(AttentionRequest::prefill(1, 5, seq, d, heads, 1, q, k, v))
        .unwrap();
    assert!(resp.output.is_ok(), "{:?}", resp.output);
    let (dq, dk, dv) =
        (rng.normal_matrix(heads, d), rng.normal_matrix(1, d), rng.normal_matrix(1, d));
    assert!(coord
        .submit_wait(AttentionRequest::decode(2, 5, 0, d, heads, 1, dq, dk, dv))
        .unwrap()
        .output
        .is_ok());

    // Now the pipelined burst: close, re-prefill (same id, same
    // length — the resident dead stream is the same size, the worst
    // case), and a decode of the NEW incarnation, submitted without
    // waiting.  The queue must keep them in session order.
    let close_rx = coord.submit(AttentionRequest::close(3, 5)).unwrap();
    let q2 = rng.normal_matrix(heads * seq, d);
    let k2 = rng.normal_matrix(seq, d);
    let v2 = rng.normal_matrix(seq, d);
    let prefill_rx = coord
        .submit(AttentionRequest::prefill(4, 5, seq, d, heads, 1, q2, k2.clone(), v2.clone()))
        .unwrap();
    let dq2 = rng.normal_matrix(heads, d);
    let dk2 = rng.normal_matrix(1, d);
    let dv2 = rng.normal_matrix(1, d);
    let decode_rx = coord
        .submit(AttentionRequest::decode(
            5, 5, 0, d, heads, 1,
            dq2.clone(), dk2.clone(), dv2.clone(),
        ))
        .unwrap();

    assert!(close_rx.recv().unwrap().output.is_ok());
    assert!(prefill_rx.recv().unwrap().output.is_ok());
    let got = decode_rx.recv().unwrap().output.expect("reused-id decode succeeds");

    // Oracle: the decode over the SECOND incarnation's K/V, computed
    // exactly as the device's reference backend computes it.  Stale
    // predecessor K/V would change every element.
    let mut full_k = k2;
    full_k.extend_from_slice(&dk2);
    let mut full_v = v2;
    full_v.extend_from_slice(&dv2);
    let mut want = Vec::with_capacity(heads * d);
    for h in 0..heads {
        want.extend_from_slice(&decode_pwl(
            &dq2[h * d..(h + 1) * d],
            &full_k,
            &full_v,
            d,
            array,
            segments,
        ));
    }
    assert_eq!(got, want, "reused id served the dead incarnation's K/V");

    assert!(coord.submit_wait(AttentionRequest::close(6, 5)).unwrap().output.is_ok());
    let o = Ordering::Relaxed;
    assert_eq!(coord.metrics.sessions_opened.load(o), 2);
    assert_eq!(coord.metrics.sessions_closed.load(o), 2);
    coord.shutdown();
}
