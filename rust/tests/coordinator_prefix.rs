//! Cross-session prefix-cache serving tests (DESIGN.md §11, ISSUE
//! acceptance): the full coordinator path with `prefix_cache = on` —
//! admission-time hash-walk + byte-verified matching, suffix-only
//! resumed prefill on the devices, refcounted page attach, and the
//! budget/placement bookkeeping — pinned against the cold run.
//!
//! The load-bearing invariant: a cache-shared prefill computes **only**
//! the uncovered suffix query rows, and those rows — plus every
//! subsequent decode step of the warm session — are **bitwise
//! identical** to the same workload served with the prefix cache off.
//! Asserted across the reference and cycle-accurate sim backends,
//! masks {none, causal}, and seq_shards {1, 2}.

use fsa::config::{BackendKind, RunConfig};
use fsa::coordinator::request::AttentionRequest;
use fsa::coordinator::Coordinator;
use fsa::mask::MaskKind;
use fsa::numerics::SplitMix64;

const SEQ: usize = 48;
const D: usize = 16;
/// Tokens sessions 101 and 202 share (a whole number of PAGE-token
/// blocks strictly below SEQ, so the match covers exactly this much).
const SHARED: usize = 32;
const PAGE: usize = 16;
const HEADS: usize = 4;
const KV: usize = 2;
const DECODE_STEPS: u64 = 4;

/// Deterministic per-tensor content: the two coordinators (cache on /
/// cache off) must see byte-identical workloads.
fn mat(tag: u64, rows: usize, d: usize) -> Vec<f32> {
    SplitMix64::new(0x9E37 + tag).normal_matrix(rows, d)
}

/// `fresh` with each KV head's first `shared` rows replaced by `base`'s
/// (head-major `(kv_heads, seq, d)` layout).
fn with_shared_prefix(base: &[f32], fresh: &[f32], shared: usize) -> Vec<f32> {
    let mut out = fresh.to_vec();
    let stride = SEQ * D;
    for h in 0..KV {
        out[h * stride..h * stride + shared * D]
            .copy_from_slice(&base[h * stride..h * stride + shared * D]);
    }
    out
}

struct Run {
    /// Outputs in submission order: donor prefill, warm prefill, then
    /// `DECODE_STEPS` decode steps of the warm session.
    outputs: Vec<Vec<f32>>,
    warm_reused: usize,
    prefix_hits: u64,
    prefix_misses: u64,
    attached_pages: u64,
    saved_cycles: u64,
}

/// Serve the fixed two-session workload: donor prefill, a second
/// prefill sharing the donor's first SHARED tokens of K/V (fresh Q and
/// tail), close the donor mid-stream, then decode the warm session.
fn run_workload(
    prefix_cache: bool,
    backend: BackendKind,
    mask: MaskKind,
    seq_shards: usize,
) -> Run {
    let cfg = RunConfig {
        devices: 1,
        max_batch: 8,
        batch_timeout_cycles: 50_000,
        queue_depth: 64,
        backend,
        num_heads: HEADS,
        num_kv_heads: KV,
        kv_cache_pages: 256,
        kv_page_size: PAGE,
        prefix_cache,
        seq_shards,
        sim_max_seq: 512,
        array_size: 16,
        ..RunConfig::default()
    };
    let coord = Coordinator::start(cfg).unwrap();
    let mut outputs = Vec::new();

    let k1 = mat(12, KV * SEQ, D);
    let v1 = mat(13, KV * SEQ, D);
    let donor = AttentionRequest::prefill(
        1, 101, SEQ, D, HEADS, KV,
        mat(11, HEADS * SEQ, D), k1.clone(), v1.clone(),
    )
    .with_mask(mask);
    let resp = coord.submit_wait(donor).unwrap();
    outputs.push(resp.output.expect("donor prefill"));

    let warm = AttentionRequest::prefill(
        2, 202, SEQ, D, HEADS, KV,
        mat(21, HEADS * SEQ, D),
        with_shared_prefix(&k1, &mat(22, KV * SEQ, D), SHARED),
        with_shared_prefix(&v1, &mat(23, KV * SEQ, D), SHARED),
    )
    .with_mask(mask);
    let resp = coord.submit_wait(warm).unwrap();
    let warm_reused = resp.stats.prefix_reused_tokens;
    outputs.push(resp.output.expect("warm prefill"));

    // Retire the donor mid-stream: shared device pages must survive on
    // the warm session's references alone (refcounts, not liveness).
    assert!(coord.submit_wait(AttentionRequest::close(3, 101)).unwrap().output.is_ok());

    for step in 0..DECODE_STEPS {
        let req = AttentionRequest::decode(
            10 + step, 202, step, D, HEADS, KV,
            mat(30 + step, HEADS, D),
            mat(40 + step, KV, D),
            mat(50 + step, KV, D),
        );
        let resp = coord.submit_wait(req).unwrap();
        outputs.push(resp.output.expect("decode step"));
    }

    let o = std::sync::atomic::Ordering::Relaxed;
    let run = Run {
        outputs,
        warm_reused,
        prefix_hits: coord.metrics.prefix_hits.load(o),
        prefix_misses: coord.metrics.prefix_misses.load(o),
        attached_pages: coord.metrics.prefix_attached_pages.load(o),
        saved_cycles: coord.metrics.saved_prefill_cycles.load(o),
    };
    coord.shutdown();
    run
}

/// The pinned contract for one (backend, mask, seq_shards) cell.
fn assert_warm_equals_cold(backend: BackendKind, mask: MaskKind, seq_shards: usize) {
    let cold = run_workload(false, backend, mask, seq_shards);
    let warm = run_workload(true, backend, mask, seq_shards);
    let tag = format!("{backend:?}/{mask}/shards={seq_shards}");

    // Cache off: nothing matched, nothing counted, full outputs.
    assert_eq!(cold.warm_reused, 0, "{tag}");
    assert_eq!((cold.prefix_hits, cold.prefix_misses), (0, 0), "{tag}");
    assert_eq!(cold.outputs[1].len(), HEADS * SEQ * D, "{tag}");

    // Cache on: the donor missed (nothing indexed yet), the second
    // prefill matched exactly the shared SHARED-token block run.
    assert_eq!((warm.prefix_hits, warm.prefix_misses), (1, 1), "{tag}");
    assert_eq!(warm.warm_reused, SHARED, "{tag}");
    assert!(warm.saved_cycles > 0, "{tag}: resumed prefill must save modeled cycles");

    // The donor's own prefill ran identically under both configs.
    assert_eq!(cold.outputs[0], warm.outputs[0], "{tag}: donor prefill diverged");

    // The warm prefill carries only the uncovered suffix rows, and
    // they are bitwise the cold run's rows [SHARED..SEQ) per head.
    let suffix = SEQ - SHARED;
    assert_eq!(warm.outputs[1].len(), HEADS * suffix * D, "{tag}");
    for h in 0..HEADS {
        let cold_rows = &cold.outputs[1][h * SEQ * D + SHARED * D..(h + 1) * SEQ * D];
        let warm_rows = &warm.outputs[1][h * suffix * D..(h + 1) * suffix * D];
        assert_eq!(cold_rows, warm_rows, "{tag}: head {h} suffix rows diverged");
    }

    // Every decode step after the resumed prefill is bitwise the cold
    // run's — including past the donor's close.
    for (i, (c, w)) in cold.outputs[2..].iter().zip(&warm.outputs[2..]).enumerate() {
        assert_eq!(c, w, "{tag}: decode step {i} diverged");
    }
}

#[test]
fn reference_backend_whole_sequence_is_bitwise_cold() {
    assert_warm_equals_cold(BackendKind::Reference, MaskKind::None, 1);
    assert_warm_equals_cold(BackendKind::Reference, MaskKind::Causal, 1);
}

#[test]
fn reference_backend_seq_sharded_is_bitwise_cold() {
    assert_warm_equals_cold(BackendKind::Reference, MaskKind::None, 2);
    assert_warm_equals_cold(BackendKind::Reference, MaskKind::Causal, 2);
}

#[test]
fn sim_backend_whole_sequence_is_bitwise_cold() {
    assert_warm_equals_cold(BackendKind::Sim, MaskKind::None, 1);
    assert_warm_equals_cold(BackendKind::Sim, MaskKind::Causal, 1);
}

#[test]
fn sim_backend_seq_sharded_is_bitwise_cold() {
    assert_warm_equals_cold(BackendKind::Sim, MaskKind::None, 2);
    assert_warm_equals_cold(BackendKind::Sim, MaskKind::Causal, 2);
}

/// On one device the warm session's KV streams find the donor's pages
/// resident and attach the shared prefix by refcount instead of
/// copying (the device-tier half of the tentpole).
#[test]
fn shared_prefix_pages_attach_instead_of_copying() {
    let warm = run_workload(true, BackendKind::Reference, MaskKind::None, 1);
    assert!(
        warm.attached_pages > 0,
        "warm prefill on the donor's device must attach shared pages, got 0"
    );
}
