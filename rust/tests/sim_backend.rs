//! The §8 bitwise contract at the backend level: every `SimBackend`
//! execution path — whole heads, sequence chunks, resumed (prefix-warm)
//! prefills, decode rows, split-KV decode ranges — must produce outputs
//! bitwise-identical to the reference twin it claims to mirror (they
//! share the PWL exp2, the fp16 quantization points and the
//! accumulation orders; the §8 mask wave covers partial tiles and
//! zero-padded ragged tails).  Also the sim-determinism and
//! structural-hazard satellites: the machine is a pure function of
//! (program, memory image), and the new decode-row / partial program
//! shapes survive the array's port-hazard asserts.
//!
//! Everything drives the single typed entry point
//! (`execute(ShardPlan) -> ShardOutput`, DESIGN.md §11) — the old
//! four-method surface is gone.
//!
//! Machine-verified twin: python/tests/test_sim_backend_bitwise.py runs
//! the same comparison as a float32/float16 numpy port.

use fsa::config::{AccelConfig, BackendKind};
use fsa::kernel::flash::{flash_chunk_program, ChunkLayout, ChunkParams};
use fsa::mask::MaskKind;
use fsa::numerics::reference::{
    decode_pwl, decode_pwl_partial, flash_pwl_masked, flash_pwl_partial, flash_pwl_resumed,
    FlashPartial, Mat,
};
use fsa::numerics::SplitMix64;
use fsa::runtime::{Backend, ShardPlan, SimBackend};
use fsa::sim::{Machine, MachineConfig};

const N: usize = 32;
const SEGMENTS: usize = 8;

fn accel() -> AccelConfig {
    let mut cfg = AccelConfig::builtin("fsa").unwrap();
    cfg.array_size = N;
    cfg
}

fn sim() -> SimBackend {
    SimBackend::new(&accel())
}

fn head(
    be: &mut SimBackend,
    l: usize,
    d: usize,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: MaskKind,
) -> Result<Vec<f32>, String> {
    be.execute(ShardPlan::Head { seq_len: l, d, q, k, v, mask })?.into_full()
}

#[allow(clippy::too_many_arguments)]
fn chunk(
    be: &mut SimBackend,
    l: usize,
    d: usize,
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    mask: MaskKind,
    key_offset: usize,
) -> Result<FlashPartial, String> {
    be.execute(ShardPlan::HeadChunk {
        seq_len: l,
        d,
        q,
        k_chunk: kc,
        v_chunk: vc,
        mask,
        key_offset,
        total_keys: l,
    })?
    .into_partial()
}

fn decode(
    be: &mut SimBackend,
    prefix: usize,
    d: usize,
    qr: &[f32],
    k: &[f32],
    v: &[f32],
) -> Result<Vec<f32>, String> {
    be.execute(ShardPlan::DecodeRow { prefix_len: prefix, d, q_row: qr, k, v })?.into_full()
}

fn decode_range(
    be: &mut SimBackend,
    range: usize,
    d: usize,
    qr: &[f32],
    k: &[f32],
    v: &[f32],
) -> Result<FlashPartial, String> {
    be.execute(ShardPlan::DecodeRange { range_len: range, d, q_row: qr, k, v })?.into_partial()
}

#[test]
fn execute_head_is_bitwise_the_reference_twin() {
    // Shapes: exact tiles, ragged rows+cols, padded head dim (d < N);
    // masks: none, causal, mid-tile key padding.
    let mut rng = SplitMix64::new(81);
    let mut be = sim();
    for &(l, d) in &[(64usize, 32usize), (40, 16), (33, 8), (96, 32)] {
        let q = rng.normal_matrix(l, d);
        let k = rng.normal_matrix(l, d);
        let v = rng.normal_matrix(l, d);
        for mask in [
            MaskKind::None,
            MaskKind::Causal,
            MaskKind::PaddingKeys { valid: l - l / 3 },
        ] {
            let got = head(&mut be, l, d, &q, &k, &v, mask).unwrap();
            let want = flash_pwl_masked(
                &Mat::new(l, d, q.clone()),
                &Mat::new(l, d, k.clone()),
                &Mat::new(l, d, v.clone()),
                N,
                N,
                SEGMENTS,
                mask,
            );
            assert_eq!(got, want.data, "L={l} d={d} {mask:?}");
        }
    }
    // A fully-masked operator returns the defined zero output without
    // running the array.
    let q = rng.normal_matrix(8, 8);
    let got = head(&mut be, 8, 8, &q, &q, &q, MaskKind::PaddingKeys { valid: 0 }).unwrap();
    assert!(got.iter().all(|&x| x == 0.0));
}

#[test]
fn execute_head_partial_is_bitwise_the_reference_twin() {
    // Sequence-parallel chunks at global key coordinates, including a
    // chunk the causal mask partially kills (row block 0 of the second
    // half sees nothing) — its rows must stay the empty merge-identity
    // state, bitwise like the reference partial.
    let mut rng = SplitMix64::new(82);
    let mut be = sim();
    let (l, d) = (64usize, 32usize);
    let q = rng.normal_matrix(l, d);
    let k = rng.normal_matrix(l, d);
    let v = rng.normal_matrix(l, d);
    for mask in [MaskKind::None, MaskKind::Causal, MaskKind::PaddingKeys { valid: 40 }] {
        for &(start, len) in &[(0usize, 32usize), (32, 32), (16, 48)] {
            let got = chunk(
                &mut be,
                l,
                d,
                &q,
                &k[start * d..(start + len) * d],
                &v[start * d..(start + len) * d],
                mask,
                start,
            )
            .unwrap();
            let want = flash_pwl_partial(
                &Mat::new(l, d, q.clone()),
                &Mat::new(len, d, k[start * d..(start + len) * d].to_vec()),
                &Mat::new(len, d, v[start * d..(start + len) * d].to_vec()),
                N,
                N,
                SEGMENTS,
                mask,
                start,
                l,
            );
            assert_eq!(got, want, "{mask:?} chunk [{start}, {})", start + len);
        }
    }
}

/// DESIGN.md §11: a resumed (prefix-cache warm) prefill computes only
/// the uncovered suffix query rows, with the mask programmed at global
/// query coordinates — so the suffix rows are bitwise the cold whole-
/// head run's same rows, whole-range and per-key-chunk alike.
#[test]
fn resumed_prefill_rows_are_bitwise_the_cold_suffix() {
    let mut rng = SplitMix64::new(89);
    let mut be = sim();
    let (l, d) = (64usize, 16usize);
    let q = rng.normal_matrix(l, d);
    let k = rng.normal_matrix(l, d);
    let v = rng.normal_matrix(l, d);
    for mask in [MaskKind::None, MaskKind::Causal, MaskKind::PaddingKeys { valid: 40 }] {
        let cold = head(&mut be, l, d, &q, &k, &v, mask).unwrap();
        for &resume in &[16usize, 33] {
            // Whole key range: normalized suffix rows out, bitwise the
            // cold run's same rows.
            let warm = be
                .execute(ShardPlan::ResumedPrefill {
                    seq_len: l,
                    d,
                    query_offset: resume,
                    q_suffix: &q[resume * d..],
                    k_chunk: &k,
                    v_chunk: &v,
                    mask,
                    key_offset: 0,
                    total_keys: l,
                })
                .unwrap()
                .into_full()
                .unwrap();
            assert_eq!(warm, cold[resume * d..], "{mask:?} resume {resume} whole-range");
            // Split key range: partial states, each bitwise the
            // reference resumed twin at the same global coordinates.
            let split = 32usize;
            let rows = l - resume;
            for &(start, len) in &[(0usize, split), (split, l - split)] {
                let warm_part = be
                    .execute(ShardPlan::ResumedPrefill {
                        seq_len: l,
                        d,
                        query_offset: resume,
                        q_suffix: &q[resume * d..],
                        k_chunk: &k[start * d..(start + len) * d],
                        v_chunk: &v[start * d..(start + len) * d],
                        mask,
                        key_offset: start,
                        total_keys: l,
                    })
                    .unwrap()
                    .into_partial()
                    .unwrap();
                let want = flash_pwl_resumed(
                    &Mat::new(rows, d, q[resume * d..].to_vec()),
                    &Mat::new(len, d, k[start * d..(start + len) * d].to_vec()),
                    &Mat::new(len, d, v[start * d..(start + len) * d].to_vec()),
                    N,
                    N,
                    SEGMENTS,
                    mask,
                    resume,
                    start,
                    l,
                );
                assert_eq!(
                    warm_part, want,
                    "{mask:?} resume {resume} chunk [{start}, {})",
                    start + len
                );
            }
        }
    }
    // A resume point that leaves no suffix rows is reported, not run.
    assert!(be
        .execute(ShardPlan::ResumedPrefill {
            seq_len: l,
            d,
            query_offset: l,
            q_suffix: &[],
            k_chunk: &k,
            v_chunk: &v,
            mask: MaskKind::None,
            key_offset: 0,
            total_keys: l,
        })
        .is_err());
}

#[test]
fn execute_decode_rows_are_bitwise_the_reference_twin() {
    let mut rng = SplitMix64::new(83);
    let mut be = sim();
    for &(prefix, d) in &[(37usize, 32usize), (64, 16), (96, 32), (5, 8)] {
        let qr = rng.normal_matrix(1, d);
        let k = rng.normal_matrix(prefix, d);
        let v = rng.normal_matrix(prefix, d);
        let got = decode(&mut be, prefix, d, &qr, &k, &v).unwrap();
        assert_eq!(
            got,
            decode_pwl(&qr, &k, &v, d, N, SEGMENTS),
            "decode prefix={prefix} d={d}"
        );
        let part = decode_range(&mut be, prefix, d, &qr, &k, &v).unwrap();
        assert_eq!(
            part,
            decode_pwl_partial(&qr, &k, &v, d, N, SEGMENTS),
            "decode partial prefix={prefix} d={d}"
        );
    }
    // Shape mismatches are reported, not panicked.
    let qr = rng.normal_matrix(1, 8);
    assert!(decode(&mut be, 4, 8, &qr, &qr, &qr).is_err());
}

#[test]
fn backend_enum_routes_sim_and_reports_measured_cycles() {
    let cfg = accel();
    let mut be = Backend::new(BackendKind::Sim, std::path::Path::new("/nonexistent"), &cfg)
        .unwrap();
    assert_eq!(be.name(), "sim");
    assert!(be.take_measured().is_none(), "nothing executed yet");
    let mut rng = SplitMix64::new(84);
    let (l, d) = (64usize, 32usize);
    let q = rng.normal_matrix(l, d);
    let out = be
        .execute(ShardPlan::Head { seq_len: l, d, q: &q, k: &q, v: &q, mask: MaskKind::Causal })
        .unwrap()
        .into_full()
        .unwrap();
    assert_eq!(out.len(), l * d);
    let measured = be.take_measured().expect("sim executions measure cycles");
    assert!(measured > 0);
    assert!(be.take_measured().is_none(), "take consumes the measurement");
    // The reference backend never measures.
    let mut rb =
        Backend::new(BackendKind::Reference, std::path::Path::new("/nonexistent"), &cfg).unwrap();
    rb.execute(ShardPlan::Head { seq_len: l, d, q: &q, k: &q, v: &q, mask: MaskKind::None })
        .unwrap();
    assert!(rb.take_measured().is_none());
}

/// Satellite: sim determinism — the same program on the same memory
/// image three times yields identical `RunStats` and an identical
/// memory image (the machine is a pure function of its inputs; no
/// hidden state leaks between runs).
#[test]
fn sim_is_deterministic_across_identical_runs() {
    let p = ChunkParams::whole(N, 64, MaskKind::Causal);
    let layout = ChunkLayout::packed(&p);
    let prog = flash_chunk_program(&p, &layout).unwrap();
    let mut rng = SplitMix64::new(85);
    let data = rng.normal_matrix(p.padded_queries(), N);

    let run = || {
        let mut mc = MachineConfig::from_accel(&accel());
        mc.mem_elems = layout.mem_elems(&p).max(1 << 12);
        let mut m = Machine::new(mc);
        m.write_mem(layout.q_addr, &data);
        m.write_mem(layout.k_addr, &data);
        m.write_mem(layout.v_addr, &data);
        let stats = m.run_program(&prog).unwrap();
        let image = m.read_mem(0, layout.mem_elems(&p)).to_vec();
        (stats, image)
    };
    let (s1, img1) = run();
    for round in 0..2 {
        let (s2, img2) = run();
        assert_eq!(s1.cycles, s2.cycles, "round {round}");
        assert_eq!(s1.matmul_macs, s2.matmul_macs, "round {round}");
        assert_eq!(s1.total_pe_ops, s2.total_pe_ops, "round {round}");
        assert_eq!(s1.dma_load_busy, s2.dma_load_busy, "round {round}");
        assert_eq!(s1.dma_store_busy, s2.dma_store_busy, "round {round}");
        assert_eq!(s1.compute_busy, s2.compute_busy, "round {round}");
        assert_eq!(s1.instructions, s2.instructions, "round {round}");
        let b1: Vec<u32> = img1.iter().map(|x| x.to_bits()).collect();
        let b2: Vec<u32> = img2.iter().map(|x| x.to_bits()).collect();
        assert_eq!(b1, b2, "memory images must be bitwise identical (round {round})");
    }
}

/// Satellite: shard batching (DESIGN.md §8) — a backend that lets
/// several shards share one machine between `reset_for_reuse` hazard
/// fences produces bitwise-identical outputs, partial states and
/// measured cycle counts to a backend allocating a fresh machine per
/// shard, across a mixed stream of shapes, masks and execute paths —
/// and stays deterministic across three batched repetitions.
#[test]
fn shard_batching_is_bitwise_and_cycle_equal_to_fresh_machines() {
    #[derive(Debug, PartialEq)]
    enum Out {
        Head(Vec<u32>, u64),
        Partial(Vec<u32>, Vec<u32>, Vec<u32>, u64),
    }
    let run = |shards: usize| -> Vec<Out> {
        let mut be = sim();
        be.set_batch_shards(shards);
        let mut rng = SplitMix64::new(88);
        let mut outs = Vec::new();
        // Mixed shard stream: whole heads of different shapes + masks,
        // a chunk with partial state, a resumed suffix, a decode row, a
        // decode range — all between the same pair of hazard fences
        // when batched.
        for &(l, d, mask) in &[
            (64usize, 32usize, MaskKind::Causal),
            (40, 16, MaskKind::None),
            (33, 8, MaskKind::PaddingKeys { valid: 20 }),
            (96, 32, MaskKind::Causal),
        ] {
            let q = rng.normal_matrix(l, d);
            let k = rng.normal_matrix(l, d);
            let v = rng.normal_matrix(l, d);
            let o = head(&mut be, l, d, &q, &k, &v, mask).unwrap();
            outs.push(Out::Head(
                o.iter().map(|x| x.to_bits()).collect(),
                be.take_measured().unwrap(),
            ));
        }
        let (l, d) = (64usize, 16usize);
        let q = rng.normal_matrix(l, d);
        let kc = rng.normal_matrix(32, d);
        let vc = rng.normal_matrix(32, d);
        let p = be
            .execute(ShardPlan::HeadChunk {
                seq_len: l,
                d,
                q: &q,
                k_chunk: &kc,
                v_chunk: &vc,
                mask: MaskKind::Causal,
                key_offset: 16,
                total_keys: l,
            })
            .unwrap()
            .into_partial()
            .unwrap();
        outs.push(Out::Partial(
            p.acc.iter().map(|x| x.to_bits()).collect(),
            p.m.iter().map(|x| x.to_bits()).collect(),
            p.l.iter().map(|x| x.to_bits()).collect(),
            be.take_measured().unwrap(),
        ));
        let kk = rng.normal_matrix(l, d);
        let vv = rng.normal_matrix(l, d);
        let o = be
            .execute(ShardPlan::ResumedPrefill {
                seq_len: l,
                d,
                query_offset: 24,
                q_suffix: &q[24 * d..],
                k_chunk: &kk,
                v_chunk: &vv,
                mask: MaskKind::Causal,
                key_offset: 0,
                total_keys: l,
            })
            .unwrap()
            .into_full()
            .unwrap();
        outs.push(Out::Head(
            o.iter().map(|x| x.to_bits()).collect(),
            be.take_measured().unwrap(),
        ));
        let qr = rng.normal_matrix(1, d);
        let k = rng.normal_matrix(50, d);
        let v = rng.normal_matrix(50, d);
        let o = decode(&mut be, 50, d, &qr, &k, &v).unwrap();
        outs.push(Out::Head(
            o.iter().map(|x| x.to_bits()).collect(),
            be.take_measured().unwrap(),
        ));
        let pr = decode_range(&mut be, 50, d, &qr, &k, &v).unwrap();
        outs.push(Out::Partial(
            pr.acc.iter().map(|x| x.to_bits()).collect(),
            pr.m.iter().map(|x| x.to_bits()).collect(),
            pr.l.iter().map(|x| x.to_bits()).collect(),
            be.take_measured().unwrap(),
        ));
        outs
    };
    let fresh = run(1);
    let batched = run(4);
    assert_eq!(fresh, batched, "batched shards must match fresh machines");
    // Determinism of the batched path itself (3 runs total).
    assert_eq!(batched, run(4));
    assert_eq!(batched, run(4));
}

/// Satellite (DESIGN.md §12): the persistent machine pool makes
/// explicit grow-or-keep decisions instead of churning — a too-small
/// resident machine is *grown* into a replacement that carries its
/// capacities (not silently dropped), a covering resident is kept
/// across arbitrarily many shards (no use cap), and
/// `sim_batch_shards = 1` still allocates fresh per shard (the
/// cycle-equality oracle's twin).  Observed through the
/// `machines_allocated` hot-path counter.
#[test]
fn machine_pool_grows_on_demand_and_never_churns() {
    let mut rng = SplitMix64::new(90);
    let small_q = rng.normal_matrix(32, 16);
    let big_q = rng.normal_matrix(96, 32);

    let mut be = sim(); // pooling on (default batch_shards = 8)
    head(&mut be, 32, 16, &small_q, &small_q, &small_q, MaskKind::None).unwrap();
    assert_eq!(be.hotpath_stats().machines_allocated, 1, "first shard allocates");

    // Bigger shard: the resident is too small — grow (one replacement),
    // not drop-and-thrash.
    head(&mut be, 96, 32, &big_q, &big_q, &big_q, MaskKind::Causal).unwrap();
    assert_eq!(be.hotpath_stats().machines_allocated, 2, "growth allocates once");

    // The grown machine covers BOTH shapes: alternating small/big for
    // far more shards than the old 8-use cap must not allocate again.
    for round in 0..10 {
        head(&mut be, 32, 16, &small_q, &small_q, &small_q, MaskKind::None).unwrap();
        head(&mut be, 96, 32, &big_q, &big_q, &big_q, MaskKind::Causal).unwrap();
        assert_eq!(
            be.hotpath_stats().machines_allocated,
            2,
            "round {round}: resident machine must be kept, not churned"
        );
    }

    // take() drains the counters; the next take sees only new work.
    let drained = be.take_hotpath_stats();
    assert_eq!(drained.machines_allocated, 2);
    assert_eq!(be.hotpath_stats(), Default::default());

    // Reuse-off twin: every shard allocates fresh.
    let mut fresh = sim();
    fresh.set_batch_shards(1);
    for _ in 0..3 {
        head(&mut fresh, 32, 16, &small_q, &small_q, &small_q, MaskKind::None).unwrap();
    }
    assert_eq!(fresh.hotpath_stats().machines_allocated, 3);
}

/// Tentpole contract (DESIGN.md §12): the compiled-program cache may
/// only remove host work — cache-on vs cache-off is bitwise-identical
/// in outputs AND identical in measured cycles and `CycleBreakdown`,
/// across every execute path.  Also pins the counter semantics: the
/// cache-on twin reports hits on repeated shapes with strictly fewer
/// builds (misses) than lookups, the cache-off twin reports every
/// lookup as a miss.
#[test]
fn prog_cache_on_off_is_bitwise_and_cycle_identical() {
    #[allow(clippy::type_complexity)]
    let run = |cache_entries: usize| -> (Vec<(Vec<u32>, u64, fsa::sim::CycleBreakdown)>, fsa::runtime::HotpathStats) {
        let mut be = sim();
        be.set_prog_cache(cache_entries);
        let mut rng = SplitMix64::new(91);
        let mut outs = Vec::new();
        let mut push = |be: &mut SimBackend, bits: Vec<u32>| {
            let cycles = be.take_measured().unwrap();
            let bd = be.take_measured_breakdown().unwrap();
            assert_eq!(bd.total(), cycles);
            outs.push((bits, cycles, bd));
        };
        // Two identical passes over a mixed stream: the second pass is
        // all repeated shapes, so a cache can only hit there.
        let (l, d) = (64usize, 32usize);
        let q = rng.normal_matrix(l, d);
        let k = rng.normal_matrix(l, d);
        let v = rng.normal_matrix(l, d);
        let qr = rng.normal_matrix(1, d);
        for _pass in 0..2 {
            for mask in [MaskKind::Causal, MaskKind::None] {
                let o = head(&mut be, l, d, &q, &k, &v, mask).unwrap();
                let bits = o.iter().map(|x| x.to_bits()).collect();
                push(&mut be, bits);
            }
            let p = chunk(&mut be, l, d, &q, &k[..32 * d], &v[..32 * d], MaskKind::Causal, 0)
                .unwrap();
            let bits = p
                .acc
                .iter()
                .chain(p.m.iter())
                .chain(p.l.iter())
                .map(|x| x.to_bits())
                .collect();
            push(&mut be, bits);
            let o = decode(&mut be, 50, d, &qr, &k[..50 * d], &v[..50 * d]).unwrap();
            let bits = o.iter().map(|x| x.to_bits()).collect();
            push(&mut be, bits);
            let pr = decode_range(&mut be, 50, d, &qr, &k[..50 * d], &v[..50 * d]).unwrap();
            let bits = pr
                .acc
                .iter()
                .chain(pr.m.iter())
                .chain(pr.l.iter())
                .map(|x| x.to_bits())
                .collect();
            push(&mut be, bits);
        }
        (outs, be.take_hotpath_stats())
    };
    let (on, on_stats) = run(256);
    let (off, off_stats) = run(0);
    assert_eq!(
        on, off,
        "cache-on must be bitwise, cycle and breakdown identical to cache-off"
    );
    // Same lookups either way; only where they are served differs.
    let lookups = off_stats.prog_cache_misses;
    assert_eq!(off_stats.prog_cache_hits, 0, "disabled cache never hits");
    assert_eq!(on_stats.prog_cache_hits + on_stats.prog_cache_misses, lookups);
    // The whole second pass repeats shapes: at least half the lookups hit,
    // and strictly fewer programs were built than shards executed.
    assert!(on_stats.prog_cache_hits * 2 >= lookups, "stats: {on_stats:?}");
    assert!(on_stats.prog_cache_misses < lookups, "stats: {on_stats:?}");
}

/// Satellite: structural-hazard regression for the new decode-row
/// program shape — the array panics on any port conflict, so merely
/// completing these runs proves the br = 1 and masked-ragged schedules
/// stay legal.  (The masked/partial shapes are exercised the same way
/// by every bitwise test above.)
#[test]
fn decode_row_program_shape_is_hazard_free() {
    let mut rng = SplitMix64::new(86);
    let mut be = sim();
    for prefix in [1usize, 31, 32, 33, 95] {
        let qr = rng.normal_matrix(1, N);
        let k = rng.normal_matrix(prefix, N);
        let v = rng.normal_matrix(prefix, N);
        // A panic here IS the failure; the output check is a bonus.
        let out = decode(&mut be, prefix, N, &qr, &k, &v).unwrap();
        assert!(out.iter().all(|x| x.is_finite()));
        assert!(be.take_measured().unwrap() > 0);
    }
}

/// Satellite: mask-aware utilization — denominated in *issued* tile
/// work, a perfectly-scheduled causal run scores in the same band as
/// its square sibling instead of looking half as efficient (or, via the
/// streamed-MAC counter, twice as busy as its useful work).
#[test]
fn masked_utilization_is_causal_vs_square_consistent() {
    let run = |mask: MaskKind, l: usize| {
        let p = ChunkParams::whole(N, l, mask);
        let layout = ChunkLayout::packed(&p);
        let prog = flash_chunk_program(&p, &layout).unwrap();
        let mut mc = MachineConfig::from_accel(&accel());
        mc.mem_elems = layout.mem_elems(&p).max(1 << 12);
        let mut m = Machine::new(mc);
        let mut rng = SplitMix64::new(87);
        let data = rng.normal_matrix(p.padded_queries(), N);
        m.write_mem(layout.q_addr, &data);
        m.write_mem(layout.k_addr, &data);
        m.write_mem(layout.v_addr, &data);
        m.run_program(&prog).unwrap()
    };
    let l = 128;
    let square = run(MaskKind::None, l);
    let causal = run(MaskKind::Causal, l);
    // Unmasked, exact tiling: the census equals the MAC counter, so the
    // two utilizations coincide exactly.
    assert_eq!(
        square.masked_utilization(N, l, MaskKind::None),
        square.utilization(N)
    );
    // Causal issues ~(t+1)/2t of the tiles and takes proportionally
    // fewer cycles: issued-work utilization stays in the square's band.
    let u_sq = square.utilization(N);
    let u_ca = causal.masked_utilization(N, l, MaskKind::Causal);
    assert!(
        (u_ca - u_sq).abs() < 0.07,
        "causal issued-work utilization {u_ca} vs square {u_sq}"
    );
    // The naive useful-FLOPs denomination would read ~40% lower on the
    // same run (masked diagonal lanes stream but do no useful work) —
    // the gap masked_utilization exists to remove.
    let naive = (fsa::schedule::masked_attention_flops(l, N, MaskKind::Causal) / 2) as f64
        / ((N * N) as f64 * causal.cycles as f64);
    assert!(u_ca > naive * 1.2, "issued {u_ca} vs naive useful-FLOPs {naive}");
}
