//! End-to-end masked-attention serving tests (DESIGN.md §6) on the
//! reference backend: causal prefill through the full coordinator path,
//! exact (bitwise) bucket padding via `PaddingKeys`, and causal
//! prefill → decode sessions against stateless causal recomputation.
//! No PJRT and no artifacts, so these run in every environment.

use fsa::config::{BackendKind, RunConfig};
use fsa::coordinator::request::AttentionRequest;
use fsa::coordinator::Coordinator;
use fsa::mask::MaskKind;
use fsa::numerics::reference::{flash_pwl_masked, mat_error, sdpa_masked, Mat};
use fsa::numerics::SplitMix64;

/// Array dim / PWL segments of the builtin `fsa` device config the
/// workers run: the oracles must tile the same way.
const ARRAY: usize = 128;
const SEGMENTS: usize = 8;

fn cfg(devices: usize) -> RunConfig {
    RunConfig {
        devices,
        max_batch: 8,
        batch_timeout_cycles: 50_000,
        queue_depth: 64,
        backend: BackendKind::Reference,
        num_heads: 4,
        num_kv_heads: 2,
        ..RunConfig::default()
    }
}

fn gqa_req(
    rng: &mut SplitMix64,
    id: u64,
    seq: usize,
    d: usize,
    heads: usize,
    kv: usize,
) -> AttentionRequest {
    AttentionRequest::gqa(
        id,
        seq,
        d,
        heads,
        kv,
        rng.normal_matrix(heads * seq, d),
        rng.normal_matrix(kv * seq, d),
        rng.normal_matrix(kv * seq, d),
    )
}

/// Causal GQA serving end to end: sharded across the pool, every head
/// bitwise the masked device twin, parity with masked dense SDPA, and
/// mask-aware (≈halved) FLOP accounting.
#[test]
fn causal_request_serves_exactly_across_the_pool() {
    let (seq, d, heads, kv) = (64usize, 32usize, 4usize, 2usize);
    let mut rng = SplitMix64::new(61);
    let req = gqa_req(&mut rng, 1, seq, d, heads, kv).with_mask(MaskKind::Causal);
    let square_flops = gqa_req(&mut rng, 9, seq, d, heads, kv).flops();
    assert!(req.flops() < square_flops, "causal FLOPs must be ~half");

    let coord = Coordinator::start(cfg(2)).unwrap();
    let resp = coord.submit_wait(req.clone()).unwrap();
    let out = resp.output.expect("causal serving succeeds");
    assert_eq!(resp.shards, heads);
    assert!(resp.utilization > 0.0 && resp.utilization < 1.0);

    for h in 0..heads {
        let (k, v) = req.head_kv(req.kv_head_for(h));
        let qh = Mat::new(seq, d, req.head_q(h).to_vec());
        let km = Mat::new(seq, d, k.to_vec());
        let vm = Mat::new(seq, d, v.to_vec());
        // Bitwise: the device twin with the same mask and tiling.
        let want = flash_pwl_masked(&qh, &km, &vm, ARRAY, ARRAY, SEGMENTS, MaskKind::Causal);
        assert_eq!(&out[h * seq * d..(h + 1) * seq * d], &want.data[..], "head {h}");
        // Parity: the exact masked dense reference (Table-2 band).
        let dense = sdpa_masked(&qh, &km, &vm, MaskKind::Causal);
        let got = Mat::new(seq, d, out[h * seq * d..(h + 1) * seq * d].to_vec());
        let err = mat_error(&got, &dense);
        assert!(err.mae < 2e-2, "head {h}: {err:?}");
    }
    coord.shutdown();
}

/// The tentpole exactness claim end to end: a `padded()` request served
/// through the coordinator is bitwise the unpadded request on its real
/// query rows — for unmasked (stamped `PaddingKeys`) and causal
/// requests alike.  The old residual-softmax-weight approximation is
/// gone.
#[test]
fn padded_request_is_bitwise_equal_to_unpadded() {
    let (d, heads, kv) = (16usize, 4usize, 2usize);
    let coord = Coordinator::start(cfg(2)).unwrap();
    let mut rng = SplitMix64::new(62);
    for &(seq, bucket) in &[(100usize, 128usize), (150, 256), (37, 64)] {
        for mask in [MaskKind::None, MaskKind::Causal] {
            let original = gqa_req(&mut rng, 1, seq, d, heads, kv).with_mask(mask);
            let padded = original.padded(bucket);
            match mask {
                MaskKind::None => {
                    assert_eq!(padded.mask, MaskKind::PaddingKeys { valid: seq });
                }
                m => assert_eq!(padded.mask, m),
            }

            let want = coord.submit_wait(original).unwrap().output.unwrap();
            let resp = coord.submit_wait(padded).unwrap();
            assert_eq!(resp.bucket, bucket);
            let got = resp.output.unwrap();
            // Slice the padded query rows away per head (head-major).
            for h in 0..heads {
                assert_eq!(
                    &got[h * bucket * d..h * bucket * d + seq * d],
                    &want[h * seq * d..(h + 1) * seq * d],
                    "seq {seq} bucket {bucket} {mask:?} head {h}: padding changed numerics"
                );
            }
        }
    }
    coord.shutdown();
}

/// Causal prefill → decode session: every decode step is bitwise the
/// last row of a stateless *causal* recomputation over the grown
/// sequence — decode needs no mask because the newest row's causal row
/// IS the whole prefix.
#[test]
fn causal_prefill_decode_session_matches_stateless_causal_recompute() {
    let (seq, d, heads, kv, steps) = (32usize, 16usize, 4usize, 2usize, 6usize);
    let coord = Coordinator::start(cfg(2)).unwrap();
    let mut rng = SplitMix64::new(63);

    // Client-side mirror of the full Q/K/V history, per head / KV head.
    let mut qh: Vec<Vec<f32>> = vec![Vec::new(); heads];
    let mut kh: Vec<Vec<f32>> = vec![Vec::new(); kv];
    let mut vh: Vec<Vec<f32>> = vec![Vec::new(); kv];

    let q = rng.normal_matrix(heads * seq, d);
    let k = rng.normal_matrix(kv * seq, d);
    let v = rng.normal_matrix(kv * seq, d);
    for h in 0..heads {
        qh[h].extend_from_slice(&q[h * seq * d..(h + 1) * seq * d]);
    }
    for h in 0..kv {
        kh[h].extend_from_slice(&k[h * seq * d..(h + 1) * seq * d]);
        vh[h].extend_from_slice(&v[h * seq * d..(h + 1) * seq * d]);
    }
    let prefill = AttentionRequest::prefill(1, 5, seq, d, heads, kv, q, k, v)
        .with_mask(MaskKind::Causal);
    let resp = coord.submit_wait(prefill).unwrap();
    let out = resp.output.expect("causal prefill succeeds");
    assert_eq!(coord.sessions.mask(5), Some(MaskKind::Causal));
    // The prefill response is the causal attention over the prefix.
    for h in 0..heads {
        let want = flash_pwl_masked(
            &Mat::new(seq, d, qh[h].clone()),
            &Mat::new(seq, d, kh[h / (heads / kv)].clone()),
            &Mat::new(seq, d, vh[h / (heads / kv)].clone()),
            ARRAY,
            ARRAY,
            SEGMENTS,
            MaskKind::Causal,
        );
        assert_eq!(&out[h * seq * d..(h + 1) * seq * d], &want.data[..], "prefill head {h}");
    }

    for step in 0..steps as u64 {
        let q = rng.normal_matrix(heads, d);
        let k = rng.normal_matrix(kv, d);
        let v = rng.normal_matrix(kv, d);
        for h in 0..heads {
            qh[h].extend_from_slice(&q[h * d..(h + 1) * d]);
        }
        for h in 0..kv {
            kh[h].extend_from_slice(&k[h * d..(h + 1) * d]);
            vh[h].extend_from_slice(&v[h * d..(h + 1) * d]);
        }
        let req = AttentionRequest::decode(100 + step, 5, step, d, heads, kv, q, k, v);
        let resp = coord.submit_wait(req).unwrap();
        let got = resp.output.expect("decode step succeeds");

        // Stateless causal recompute over the grown sequence; its last
        // row per head must be bitwise the decode output.
        let grown = seq + 1 + step as usize;
        for h in 0..heads {
            let kvh = h / (heads / kv);
            let full = flash_pwl_masked(
                &Mat::new(grown, d, qh[h].clone()),
                &Mat::new(grown, d, kh[kvh].clone()),
                &Mat::new(grown, d, vh[kvh].clone()),
                ARRAY,
                ARRAY,
                SEGMENTS,
                MaskKind::Causal,
            );
            assert_eq!(
                &got[h * d..(h + 1) * d],
                &full.data[(grown - 1) * d..],
                "step {step} head {h} diverged from stateless causal recompute"
            );
        }
    }

    // Masked decode steps are rejected as error responses.
    let bad = AttentionRequest::decode(
        900, 5, steps as u64, d, heads, kv,
        rng.normal_matrix(heads, d),
        rng.normal_matrix(kv, d),
        rng.normal_matrix(kv, d),
    )
    .with_mask(MaskKind::Causal);
    let resp = coord.submit_wait(bad).unwrap();
    assert!(resp.output.unwrap_err().contains("no mask"));

    coord.shutdown();
}

/// Padding-masked prefill is rejected (it would poison the host tier
/// with zero K/V rows), and a key-padding mask round-trips on stateless
/// traffic.
#[test]
fn padded_prefill_rejected_and_padding_mask_roundtrips() {
    let (seq, d) = (16usize, 8usize);
    let coord = Coordinator::start(cfg(1)).unwrap();
    let mut rng = SplitMix64::new(64);

    let padded_prefill = AttentionRequest::prefill(
        1, 3, seq, d, 2, 1,
        rng.normal_matrix(2 * seq, d),
        rng.normal_matrix(seq, d),
        rng.normal_matrix(seq, d),
    )
    .with_mask(MaskKind::PaddingKeys { valid: 8 });
    let resp = coord.submit_wait(padded_prefill).unwrap();
    assert!(resp.output.unwrap_err().contains("key-padding"));
    assert!(!coord.sessions.contains(3));

    // Stateless key-padding works and matches the masked dense oracle.
    let req = gqa_req(&mut rng, 2, seq, d, 1, 1).with_mask(MaskKind::PaddingKeys { valid: 7 });
    let resp = coord.submit_wait(req.clone()).unwrap();
    let out = resp.output.unwrap();
    let dense = sdpa_masked(
        &Mat::new(seq, d, req.q.clone()),
        &Mat::new(seq, d, req.k.clone()),
        &Mat::new(seq, d, req.v.clone()),
        MaskKind::PaddingKeys { valid: 7 },
    );
    let err = mat_error(&Mat::new(seq, d, out), &dense);
    assert!(err.mae < 2e-2, "{err:?}");
    coord.shutdown();
}
