//! Bench: L3 hot-path microbenchmarks driving the §Perf optimization pass
//! (EXPERIMENTS.md §Perf records before/after per change).
//!
//! Paths measured:
//!  1. cycle-sim array step loop (dominates every simulator experiment);
//!  2. full small-device FlashAttention run (schedule + execute);
//!  3. host flash_pwl reference (dominates Table-2 cross-checks);
//!  4. PWL exp2 scalar evaluation;
//!  5. shard dispatch with the compiled-program cache + machine pool on
//!     (the serving defaults) vs off — recorded to `BENCH_hotpath.json`
//!     (via `make bench-json`) so the programs-built ≪ shards-executed
//!     contract of DESIGN.md §12 stays diffable across PRs.
use std::time::Duration;

use fsa::benchutil::{bench_for, fmt_duration, observe, smoke, Table};
use fsa::config::AccelConfig;
use fsa::kernel::{flash_attention_program, FlashLayout, FlashParams};
use fsa::mask::MaskKind;
use fsa::numerics::pwl::PwlExp2;
use fsa::numerics::reference::{flash_pwl, Mat};
use fsa::numerics::SplitMix64;
use fsa::runtime::{ShardPlan, SimBackend};
use fsa::sim::{Machine, MachineConfig};
use fsa::telemetry::json::{parse, Json};

fn main() {
    let mut t = Table::new(&["hot path", "median", "notes"]);

    // 1 + 2: full device run at two sizes.
    for n in [16usize, 32] {
        let seq = 2 * n;
        let p = FlashParams {
            seq_len: seq,
            d: n,
            spad_elems: (6 * n * n) as u32,
            accum_elems: (n * n + n) as u32,
        };
        let layout = FlashLayout::packed(&p);
        let prog = flash_attention_program(&p, &layout).unwrap();
        let mut rng = SplitMix64::new(3);
        let data = rng.normal_matrix(seq, n);
        let st = bench_for(Duration::from_secs(1), || {
            let mut cfg = MachineConfig::small(n);
            cfg.mem_elems = layout.mem_elems(&p).max(1 << 16);
            let mut m = Machine::new(cfg);
            m.write_mem(layout.q_addr, &data);
            m.write_mem(layout.k_addr, &data);
            m.write_mem(layout.v_addr, &data);
            observe(m.run_program(&prog).unwrap());
        });
        let cycles = fsa::schedule::fsa_total_cycles(seq, n, fsa::schedule::Variant::DualPath, 8);
        t.row(&[
            format!("device run {n}x{n}, seq {seq}"),
            fmt_duration(st.median),
            format!("{:.2} sim-cycles/us", cycles as f64 / st.per_iter_ns() * 1e3),
        ]);
    }

    // 3: host oracle.
    let mut rng = SplitMix64::new(4);
    let (l, d) = (256usize, 64usize);
    let q = Mat::new(l, d, rng.normal_matrix(l, d));
    let k = Mat::new(l, d, rng.normal_matrix(l, d));
    let v = Mat::new(l, d, rng.normal_matrix(l, d));
    let st = bench_for(Duration::from_secs(1), || {
        observe(flash_pwl(&q, &k, &v, 64, 64, 8));
    });
    t.row(&[
        format!("flash_pwl oracle {l}x{d}"),
        fmt_duration(st.median),
        format!("{:.2} GFLOP/s", (4 * l * l * d) as f64 / st.per_iter_ns()),
    ]);

    // 4: scalar PWL.
    let pwl = PwlExp2::new(8);
    let xs: Vec<f32> = (0..4096).map(|i| -(i as f32) * 0.01).collect();
    let st = bench_for(Duration::from_millis(300), || {
        let mut acc = 0.0f32;
        for &x in &xs {
            acc += pwl.eval_f32(x);
        }
        observe(acc);
    });
    t.row(&[
        "pwl exp2 f32 x4096".into(),
        fmt_duration(st.median),
        format!("{:.1} Melem/s", 4096.0 / st.per_iter_ns() * 1e3),
    ]);

    // 5: shard dispatch, cached vs uncached.  One pass dispatches the
    // decode-heavy shape mix a lockstep serving round produces: two
    // same-shape heads, each a causal prefill shard plus a run of
    // decode rows over growing prefixes.  Cycle-accurate array stepping
    // dominates host time either way (the cache can only strip the
    // compile + machine-allocation overhead off the top), so the
    // headline contract in the JSON record is programs built vs shards
    // executed, not the timing delta.
    let n = 32usize;
    let accel = {
        let mut a = AccelConfig::builtin("fsa").unwrap();
        a.array_size = n;
        a
    };
    let (seq, d, decode_rows) = (2 * n, n, 6usize);
    let mut rng = SplitMix64::new(5);
    let q = rng.normal_matrix(seq, d);
    let k = rng.normal_matrix(seq, d);
    let v = rng.normal_matrix(seq, d);
    let qr = rng.normal_matrix(1, d);
    let shards_per_pass = 2 * (1 + decode_rows) as u64;
    let mut dispatch_pass = |be: &mut SimBackend| {
        for _head in 0..2 {
            observe(
                be.execute(ShardPlan::Head {
                    seq_len: seq,
                    d,
                    q: &q,
                    k: &k,
                    v: &v,
                    mask: MaskKind::Causal,
                })
                .unwrap(),
            );
            for i in 0..decode_rows {
                let prefix = seq - decode_rows + 1 + i;
                observe(
                    be.execute(ShardPlan::DecodeRow {
                        prefix_len: prefix,
                        d,
                        q_row: &qr,
                        k: &k[..prefix * d],
                        v: &v[..prefix * d],
                    })
                    .unwrap(),
                );
            }
        }
    };
    let budget = Duration::from_millis(if smoke() { 200 } else { 1000 });
    let mut modes = Vec::new();
    for cached in [true, false] {
        let mut be = SimBackend::new(&accel);
        if !cached {
            be.set_prog_cache(0);
            be.set_batch_shards(1);
        }
        // Count passes ourselves: bench_for's calibration + warmup
        // calls also dispatch shards, and the counters see every one.
        let mut passes = 0u64;
        let st = bench_for(budget, || {
            passes += 1;
            dispatch_pass(&mut be)
        });
        let hp = be.take_hotpath_stats();
        let shards = passes * shards_per_pass;
        let us_per_shard = st.per_iter_ns() / shards_per_pass as f64 / 1e3;
        if cached {
            assert!(
                hp.prog_cache_misses < shards,
                "cache on: programs built ({}) must be fewer than shards executed ({shards})",
                hp.prog_cache_misses
            );
            assert!(hp.prog_cache_hits > 0, "repeated shapes must hit the cache");
        } else {
            assert_eq!(hp.prog_cache_hits, 0, "cache off must never hit");
            assert_eq!(hp.machines_allocated, shards, "reuse off allocates per shard");
        }
        let name = if cached { "cached" } else { "uncached" };
        t.row(&[
            format!("shard dispatch n={n} ({name})"),
            fmt_duration(st.median),
            format!(
                "{us_per_shard:.1} us/shard, {} progs / {shards} shards",
                hp.prog_cache_misses
            ),
        ]);
        let mut j = Json::obj();
        j.set("name", Json::str(name))
            .set("median_us_per_shard", Json::Num(us_per_shard))
            .set("shards_executed", Json::u64(shards))
            .set("programs_built", Json::u64(hp.prog_cache_misses))
            .set("prog_cache_hits", Json::u64(hp.prog_cache_hits))
            .set("machines_allocated", Json::u64(hp.machines_allocated));
        modes.push(j);
    }

    println!("{}", t.to_string());

    let mut sweep = Json::obj();
    sweep
        .set("array_size", Json::u64(n as u64))
        .set("seq", Json::u64(seq as u64))
        .set("decode_rows_per_head", Json::u64(decode_rows as u64))
        .set("shards_per_pass", Json::u64(shards_per_pass))
        .set("modes", Json::Arr(modes));
    let mut root = Json::obj();
    root.set("bench", Json::str("hotpath"))
        .set("smoke", Json::Bool(smoke()))
        .set("prog_cache_sweep", sweep);
    let text = root.pretty();
    parse(&text).expect("emitted BENCH_hotpath.json parses back");
    let path = "BENCH_hotpath.json";
    std::fs::write(path, &text).expect("write bench json");
    println!("[bench] wrote {path}");
}
