//! Bench: L3 hot-path microbenchmarks driving the §Perf optimization pass
//! (EXPERIMENTS.md §Perf records before/after per change).
//!
//! Paths measured:
//!  1. cycle-sim array step loop (dominates every simulator experiment);
//!  2. full small-device FlashAttention run (schedule + execute);
//!  3. host flash_pwl reference (dominates Table-2 cross-checks);
//!  4. PWL exp2 scalar evaluation;
//!  5. coordinator round trip without PJRT (batching/routing overhead).
use std::time::Duration;

use fsa::benchutil::{bench_for, fmt_duration, observe, Table};
use fsa::kernel::{flash_attention_program, FlashLayout, FlashParams};
use fsa::numerics::pwl::PwlExp2;
use fsa::numerics::reference::{flash_pwl, Mat};
use fsa::numerics::SplitMix64;
use fsa::sim::{Machine, MachineConfig};

fn main() {
    let mut t = Table::new(&["hot path", "median", "notes"]);

    // 1 + 2: full device run at two sizes.
    for n in [16usize, 32] {
        let seq = 2 * n;
        let p = FlashParams {
            seq_len: seq,
            d: n,
            spad_elems: (6 * n * n) as u32,
            accum_elems: (n * n + n) as u32,
        };
        let layout = FlashLayout::packed(&p);
        let prog = flash_attention_program(&p, &layout).unwrap();
        let mut rng = SplitMix64::new(3);
        let data = rng.normal_matrix(seq, n);
        let st = bench_for(Duration::from_secs(1), || {
            let mut cfg = MachineConfig::small(n);
            cfg.mem_elems = layout.mem_elems(&p).max(1 << 16);
            let mut m = Machine::new(cfg);
            m.write_mem(layout.q_addr, &data);
            m.write_mem(layout.k_addr, &data);
            m.write_mem(layout.v_addr, &data);
            observe(m.run_program(&prog).unwrap());
        });
        let cycles = fsa::schedule::fsa_total_cycles(seq, n, fsa::schedule::Variant::DualPath, 8);
        t.row(&[
            format!("device run {n}x{n}, seq {seq}"),
            fmt_duration(st.median),
            format!("{:.2} sim-cycles/us", cycles as f64 / st.per_iter_ns() * 1e3),
        ]);
    }

    // 3: host oracle.
    let mut rng = SplitMix64::new(4);
    let (l, d) = (256usize, 64usize);
    let q = Mat::new(l, d, rng.normal_matrix(l, d));
    let k = Mat::new(l, d, rng.normal_matrix(l, d));
    let v = Mat::new(l, d, rng.normal_matrix(l, d));
    let st = bench_for(Duration::from_secs(1), || {
        observe(flash_pwl(&q, &k, &v, 64, 64, 8));
    });
    t.row(&[
        format!("flash_pwl oracle {l}x{d}"),
        fmt_duration(st.median),
        format!("{:.2} GFLOP/s", (4 * l * l * d) as f64 / st.per_iter_ns()),
    ]);

    // 4: scalar PWL.
    let pwl = PwlExp2::new(8);
    let xs: Vec<f32> = (0..4096).map(|i| -(i as f32) * 0.01).collect();
    let st = bench_for(Duration::from_millis(300), || {
        let mut acc = 0.0f32;
        for &x in &xs {
            acc += pwl.eval_f32(x);
        }
        observe(acc);
    });
    t.row(&[
        "pwl exp2 f32 x4096".into(),
        fmt_duration(st.median),
        format!("{:.1} Melem/s", 4096.0 / st.per_iter_ns() * 1e3),
    ]);

    println!("{}", t.to_string());
}
