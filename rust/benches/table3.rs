//! Bench: regenerate paper Table 3 (FSA area breakdown, 16 nm @ 1.5 GHz)
//! plus the array-size scaling ablation the paper doesn't show.
use fsa::area::AreaBreakdown;
use fsa::benchutil::Table;
use fsa::experiments::table3_report;

fn main() {
    println!("{}", table3_report(128));
    let mut t = Table::new(&["N", "total mm^2", "overhead %"]);
    for n in [32usize, 64, 128, 256] {
        let a = AreaBreakdown::for_array(n);
        t.row(&[
            n.to_string(),
            format!("{:.2}", a.total() / 1e6),
            format!("{:.2}", 100.0 * a.overhead_fraction()),
        ]);
    }
    println!("array-size scaling (model extrapolation):\n{}", t.to_string());
}
