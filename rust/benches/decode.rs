//! Bench: decode-phase serving — per-step cost of cached vs recompute
//! decode across prefix lengths, pool-level cache-hit-aware
//! utilization, and a live coordinator run over the paged KV caches.
//!
//! Three parts:
//!
//! 1. Model sweep (instant): `perfmodel::fsa_decode_perf` across
//!    prefix lengths, cached vs recompute, with the scaling ratios
//!    printed — cached per-step cost is O(L) in streamed bytes and
//!    cycles (ratio ~2 per prefix doubling) while the miss recompute
//!    is O(L²) in cycles (ratio ~4).
//! 2. Capacity sweep: `decode_pool_perf` across hit rates — the
//!    pool-level utilization/token-rate picture as cache capacity (and
//!    thus steady-state hit rate) varies.
//! 3. Live coordinator: sessions decoding round-robin over the real
//!    per-device caches on the reference backend, ample cache vs a
//!    thrashing cache (batch x prefix x capacity), reporting measured
//!    hit rates and host token throughput.
//!
//!     cargo bench --bench decode

use std::time::Instant;

use fsa::benchutil::{smoke, Table};
use fsa::config::{AccelConfig, BackendKind, EvictionPolicy, RunConfig};
use fsa::coordinator::request::AttentionRequest;
use fsa::coordinator::Coordinator;
use fsa::numerics::SplitMix64;
use fsa::perfmodel::{decode_pool_perf, fsa_decode_perf};
use fsa::schedule::Variant;

fn model_sweep(cfg: &AccelConfig) {
    let mut t = Table::new(&[
        "prefix L", "cached cycles", "cached KiB", "miss cycles", "miss/hit",
        "hit cycle x", "hit byte x", "miss recompute x",
    ]);
    let ls = [512usize, 1024, 2048, 4096, 8192, 16384];
    let mut prev: Option<(u64, u64, u64)> = None;
    for &l in &ls {
        let hit = fsa_decode_perf(cfg, l, 128, true, Variant::DualPath, 8);
        let miss = fsa_decode_perf(cfg, l, 128, false, Variant::DualPath, 8);
        let (cx, bx, rx) = match prev {
            None => ("-".into(), "-".into(), "-".into()),
            Some((pc, pb, pr)) => (
                format!("{:.2}", hit.step_cycles as f64 / pc as f64),
                format!("{:.2}", hit.bytes_streamed as f64 / pb as f64),
                format!("{:.2}", miss.recompute_cycles as f64 / pr as f64),
            ),
        };
        t.row(&[
            l.to_string(),
            hit.step_cycles.to_string(),
            format!("{:.0}", hit.bytes_streamed as f64 / 1024.0),
            miss.total_cycles.to_string(),
            format!("{:.1}", miss.total_cycles as f64 / hit.total_cycles as f64),
            cx,
            bx,
            rx,
        ]);
        prev = Some((hit.step_cycles, hit.bytes_streamed, miss.recompute_cycles));
    }
    println!("-- decode step model: cached O(L) vs recompute O(L^2) (d=128) --");
    t.print();
    println!("(per-doubling ratios: cached ~2x cycles and bytes, recompute ~4x cycles)");
}

fn pool_sweep(cfg: &AccelConfig) {
    let mut t = Table::new(&[
        "hit rate", "step cycles", "tokens/s/session", "pool util %", "KiB/step",
    ]);
    for &hr in &[1.0f64, 0.95, 0.8, 0.5, 0.0] {
        let p = decode_pool_perf(cfg, 4096, 128, 8, 2, 4, hr, Variant::DualPath, 8);
        t.row(&[
            format!("{:.2}", hr),
            format!("{:.0}", p.critical_path_cycles),
            format!("{:.0}", p.tokens_per_sec),
            format!("{:.3}", 100.0 * p.utilization),
            format!("{:.0}", p.bytes_per_step / 1024.0),
        ]);
    }
    println!("\n-- pool-level cache-hit-aware decode (L=4096, 8q/2kv heads, 4 devices) --");
    t.print();
}

/// One live configuration: `sessions` sessions prefilled at `seq`,
/// decoded `steps` steps round-robin on `devices` devices with
/// `kv_pages` pages per device.  Returns (hit rate, tokens/s host).
fn live_run(
    sessions: usize,
    steps: usize,
    seq: usize,
    devices: usize,
    kv_pages: usize,
) -> (f64, f64) {
    let (d, heads, kv_heads) = (64usize, 4usize, 2usize);
    let coord = Coordinator::start(RunConfig {
        devices,
        max_batch: 8,
        batch_timeout_cycles: 50_000,
        queue_depth: 1024,
        artifacts_dir: "artifacts".into(),
        backend: BackendKind::Reference,
        num_heads: heads,
        num_kv_heads: kv_heads,
        kv_cache_pages: kv_pages,
        kv_page_size: 16,
        kv_eviction: EvictionPolicy::Lru,
        ..RunConfig::default()
    })
    .expect("coordinator boots on the reference backend");

    let mut rng = SplitMix64::new(1234);
    let mut id = 0u64;
    for s in 0..sessions as u64 {
        id += 1;
        let resp = coord
            .submit_wait(AttentionRequest::prefill(
                id, s, seq, d, heads, kv_heads,
                rng.normal_matrix(heads * seq, d),
                rng.normal_matrix(kv_heads * seq, d),
                rng.normal_matrix(kv_heads * seq, d),
            ))
            .expect("prefill");
        assert!(resp.output.is_ok());
    }
    let t0 = Instant::now();
    let (mut hits, mut misses) = (0usize, 0usize);
    for step in 0..steps as u64 {
        for s in 0..sessions as u64 {
            id += 1;
            let resp = coord
                .submit_wait(AttentionRequest::decode(
                    id, s, step, d, heads, kv_heads,
                    rng.normal_matrix(heads, d),
                    rng.normal_matrix(kv_heads, d),
                    rng.normal_matrix(kv_heads, d),
                ))
                .expect("decode");
            assert!(resp.output.is_ok());
            hits += resp.stats.kv_hits;
            misses += resp.stats.kv_misses;
        }
    }
    let wall = t0.elapsed();
    for s in 0..sessions as u64 {
        id += 1;
        coord.submit_wait(AttentionRequest::close(id, s)).expect("close");
    }
    coord.shutdown();
    let hit_rate = hits as f64 / (hits + misses).max(1) as f64;
    let tps = (sessions * steps) as f64 / wall.as_secs_f64();
    (hit_rate, tps)
}

fn live_sweep() {
    let steps = if smoke() { 4 } else { 24 };
    let mut t = Table::new(&[
        "sessions", "prefix L", "devices", "kv pages/dev", "measured hit %", "tokens/s (host)",
    ]);
    // batch (sessions) x prefix x cache capacity; the small-cache rows
    // thrash (sessions' working sets exceed capacity -> evictions ->
    // recompute misses), the ample rows run hot.
    let cases: &[(usize, usize, usize, usize)] = if smoke() {
        &[(2, 64, 1, 64), (2, 64, 1, 6)]
    } else {
        &[
            (1, 128, 1, 64),
            (4, 128, 2, 64),
            (4, 256, 2, 128),
            (4, 128, 1, 10),
            (8, 128, 2, 12),
        ]
    };
    for &(sessions, seq, devices, pages) in cases {
        let (hr, tps) = live_run(sessions, steps, seq, devices, pages);
        t.row(&[
            sessions.to_string(),
            seq.to_string(),
            devices.to_string(),
            pages.to_string(),
            format!("{:.1}", 100.0 * hr),
            format!("{:.0}", tps),
        ]);
    }
    println!("\n-- live decode serving (reference backend, {steps} steps/session) --");
    t.print();
}

fn main() {
    let cfg = AccelConfig::builtin("fsa").unwrap();
    model_sweep(&cfg);
    pool_sweep(&cfg);
    live_sweep();
}
