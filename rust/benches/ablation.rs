//! Bench: design-choice ablations called out in DESIGN.md —
//! §8.2 single- vs dual-path dataflow, PWL segment count vs the +10
//! window, vector-unit throughput sensitivity of the Neuron baseline,
//! and §8.3 head-dim padding waste.
use fsa::accel::baseline::{baseline_flash_perf, KernelProfile};
use fsa::benchutil::Table;
use fsa::config::AccelConfig;
use fsa::perfmodel::fsa_flash_perf;
use fsa::schedule::Variant;

fn main() {
    let fsa = AccelConfig::builtin("fsa").unwrap();

    // -- §8.2 dataflow variant --
    let mut t = Table::new(&["seq", "dual-path util%", "single-path util%", "slowdown"]);
    for seq in [2048usize, 8192, 16384] {
        let d = fsa_flash_perf(&fsa, seq, 128, Variant::DualPath, 8);
        let s = fsa_flash_perf(&fsa, seq, 128, Variant::SinglePath, 8);
        t.row(&[
            seq.to_string(),
            format!("{:.1}", 100.0 * d.utilization),
            format!("{:.1}", 100.0 * s.utilization),
            format!("{:.2}x", s.total_cycles as f64 / d.total_cycles as f64),
        ]);
    }
    println!("§8.2 single- vs dual-direction dataflow:\n{}", t.to_string());

    // -- PWL segment count: accuracy/latency trade (Fig 12 x §3.5) --
    let mut t = Table::new(&["segments", "inner latency", "util% @8192"]);
    for seg in [2usize, 4, 8, 16, 32] {
        let p = fsa_flash_perf(&fsa, 8192, 128, Variant::DualPath, seg);
        t.row(&[
            seg.to_string(),
            format!("5N+{}", 2 + seg),
            format!("{:.1}", 100.0 * p.utilization),
        ]);
    }
    println!("PWL segments vs the elementwise window:\n{}", t.to_string());

    // -- Baseline sensitivity: what if Neuron's exp engine were faster? --
    let neuron = AccelConfig::builtin("neuron-v2").unwrap();
    let base = KernelProfile::for_machine("neuron-v2").unwrap();
    let mut t = Table::new(&["exp/cycle", "scalar active%", "util% @8192"]);
    for mult in [1.0f64, 2.0, 4.0, 8.0] {
        // Re-derive with a scaled exp rate by recomputing the structural
        // model terms (scalar time scales down; tensor eventually binds).
        let scalar = (base.br * base.bc) as f64 / (base.exp_per_cycle * mult);
        let passes = 2.0 * (128f64 / 128.0) * (base.bc as f64 / 128.0);
        let tensor = passes * (base.br as f64 + 256.0) / base.tensor_eff;
        let ii = tensor.max(scalar) / base.pipeline_eff;
        let useful = 4.0 * (base.br * base.bc * 128) as f64; // FLOPs per tile
        let peak_per_cycle = 2.0 * 128.0 * 128.0;
        t.row(&[
            format!("{:.1}", base.exp_per_cycle * mult),
            format!("{:.0}", 100.0 * scalar / ii),
            format!("{:.1}", 100.0 * useful / (peak_per_cycle * ii)),
        ]);
    }
    let _ = neuron;
    println!("Neuron-v2 exp-throughput sensitivity (FSA's point: matching the\narray needs disproportionate scalar FLOPs/s):\n{}", t.to_string());

    // -- §8.3: head-dim padding (decode-phase weakness) --
    let mut t = Table::new(&["head dim", "util% @4096"]);
    for d in [128usize, 64, 32, 16] {
        let p = fsa_flash_perf(&fsa, 4096, d, Variant::DualPath, 8);
        t.row(&[d.to_string(), format!("{:.1}", 100.0 * p.utilization)]);
    }
    println!("§8.3 head-dim padding waste on the 128x128 array:\n{}", t.to_string());

    // -- baseline tile-size ablation --
    let mut t = Table::new(&["machine", "seq", "util%"]);
    for name in ["tpuv5e", "neuron-v2"] {
        let cfg = AccelConfig::builtin(name).unwrap();
        for seq in [2048usize, 16384] {
            let p = baseline_flash_perf(&cfg, seq, 128);
            t.row(&[name.into(), seq.to_string(), format!("{:.1}", 100.0 * p.utilization)]);
        }
    }
    println!("baseline utilization endpoints:\n{}", t.to_string());
}
