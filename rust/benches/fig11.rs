//! Bench: regenerate paper Figure 11 (FLOPs/s utilization of FSA vs
//! TPUv5e vs NeuronCore-v2 over sequence lengths 2048..16384).
use std::time::Duration;

use fsa::accel::{mean_ratio, paper_seq_lens, utilization_curve};
use fsa::benchutil::{bench_for, fmt_duration, observe};
use fsa::experiments::fig11_report;

fn main() {
    let lens = paper_seq_lens();
    println!("{}", fig11_report(&lens, 128));
    let fsa = utilization_curve("fsa", &lens, 128).unwrap();
    let tpu = utilization_curve("tpuv5e", &lens, 128).unwrap();
    let neuron = utilization_curve("neuron-v2", &lens, 128).unwrap();
    println!(
        "paper targets: 1.77x TPUv5e (got {:.2}), 4.83x Neuron-v2 (got {:.2})",
        mean_ratio(&fsa, &tpu),
        mean_ratio(&fsa, &neuron)
    );
    let st = bench_for(Duration::from_millis(200), || {
        observe(utilization_curve("fsa", &lens, 128).unwrap());
    });
    println!("[bench] fsa utilization curve: median {}", fmt_duration(st.median));
}
