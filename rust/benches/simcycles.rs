//! Bench: measured vs modeled cycles of the `backend=sim` serving path
//! (DESIGN.md §8) — the cross-validation sweep that keeps the analytic
//! `perfmodel` honest against the cycle-accurate machine — plus the
//! vectorization sweep: the same head shards stepped by the frozen
//! scalar-reference path and by the SoA vectorized path, reported as
//! PE-steps/s (cycles × N² per host second).  Cycle counts and outputs
//! must agree exactly between the two steppers; only the host time may
//! differ.
//!
//! Emits `BENCH_simcycles.json` (shapes, cycles, PE-steps/s both paths,
//! host wall times) so the perf trajectory is diffable across PRs; see
//! EXPERIMENTS.md §Perf log.  `make bench-json` runs just this target.

use std::time::Duration;

use fsa::benchutil::{bench_for, fmt_duration, smoke, Table};
use fsa::config::AccelConfig;
use fsa::mask::MaskKind;
use fsa::numerics::SplitMix64;
use fsa::perfmodel::{sim_cross_check, SIM_MODEL_BAND};
use fsa::runtime::{ShardPlan, SimBackend};

struct SweepRow {
    seq: usize,
    d: usize,
    mask: MaskKind,
    cycles: u64,
    scalar_wall_s: f64,
    vector_wall_s: f64,
}

/// One whole-head shard through the typed entry point (the serving
/// path's `Backend::execute` drives the same `ShardPlan::Head` arm).
fn head(be: &mut SimBackend, l: usize, d: usize, q: &[f32], k: &[f32], v: &[f32], mask: MaskKind) -> Vec<f32> {
    be.execute(ShardPlan::Head { seq_len: l, d, q, k, v, mask })
        .unwrap()
        .into_full()
        .unwrap()
}

impl SweepRow {
    fn pe_steps(&self, n: usize) -> f64 {
        self.cycles as f64 * (n * n) as f64
    }
    fn scalar_rate(&self, n: usize) -> f64 {
        self.pe_steps(n) / self.scalar_wall_s
    }
    fn vector_rate(&self, n: usize) -> f64 {
        self.pe_steps(n) / self.vector_wall_s
    }
    fn speedup(&self) -> f64 {
        self.scalar_wall_s / self.vector_wall_s
    }
}

fn main() {
    // A shrunken FSA (32-array) keeps the cycle-accurate runs fast; the
    // bandwidth/clock stay the paper's, so the DMA/compute balance is
    // representative.
    let mut cfg = AccelConfig::builtin("fsa").unwrap();
    cfg.array_size = 32;
    let n = cfg.array_size;

    let seqs: &[usize] = if smoke() { &[64, 96] } else { &[64, 96, 128, 192, 256] };
    let masks = [
        MaskKind::None,
        MaskKind::Causal,
        MaskKind::PaddingKeys { valid: 40 },
    ];

    let mut t = Table::new(&["seq", "mask", "modeled", "measured", "ratio"]);
    for &l in seqs {
        for mask in masks {
            let c = sim_cross_check(&cfg, l, mask, cfg.pwl_segments).unwrap();
            assert!(
                c.within_band(),
                "L={l} {mask}: ratio {:.3} outside {SIM_MODEL_BAND:?}",
                c.ratio
            );
            t.row(&[
                l.to_string(),
                mask.to_string(),
                c.modeled.to_string(),
                c.measured.to_string(),
                format!("{:.3}", c.ratio),
            ]);
        }
    }
    println!(
        "simcycles — measured sim cycles vs perfmodel tile-cycles \
         (band {:?}, N = {n})\n{}",
        SIM_MODEL_BAND,
        t.to_string()
    );

    // Old-vs-new stepper sweep: identical shards through the frozen
    // scalar-reference path and the vectorized SoA path.  The cycle
    // counts and the output bits are asserted equal — the vectorization
    // is only allowed to change host time.
    let shapes: &[(usize, usize, MaskKind)] = if smoke() {
        &[(64, 32, MaskKind::Causal)]
    } else {
        &[
            (64, 32, MaskKind::None),
            (96, 32, MaskKind::Causal),
            (128, 32, MaskKind::Causal),
            (192, 32, MaskKind::None),
        ]
    };
    let budget = Duration::from_millis(1500);
    let mut sca = SimBackend::new(&cfg);
    sca.set_scalar_reference(true);
    let mut vec_be = SimBackend::new(&cfg);
    let mut rows: Vec<SweepRow> = Vec::new();
    let mut t2 = Table::new(&[
        "seq", "mask", "cycles", "scalar PE/s", "vector PE/s", "speedup",
    ]);
    for &(l, d, mask) in shapes {
        let mut rng = SplitMix64::new(6);
        let q = rng.normal_matrix(l, d);
        let k = rng.normal_matrix(l, d);
        let v = rng.normal_matrix(l, d);
        let out_s = head(&mut sca, l, d, &q, &k, &v, mask);
        let cyc_s = sca.take_measured().unwrap();
        let out_v = head(&mut vec_be, l, d, &q, &k, &v, mask);
        let cyc_v = vec_be.take_measured().unwrap();
        assert_eq!(cyc_s, cyc_v, "L={l} {mask}: steppers disagree on cycles");
        let bs: Vec<u32> = out_s.iter().map(|x| x.to_bits()).collect();
        let bv: Vec<u32> = out_v.iter().map(|x| x.to_bits()).collect();
        assert_eq!(bs, bv, "L={l} {mask}: steppers disagree bitwise");
        let st_s = bench_for(budget, || {
            head(&mut sca, l, d, &q, &k, &v, mask);
        });
        let st_v = bench_for(budget, || {
            head(&mut vec_be, l, d, &q, &k, &v, mask);
        });
        let row = SweepRow {
            seq: l,
            d,
            mask,
            cycles: cyc_v,
            scalar_wall_s: st_s.median.as_secs_f64(),
            vector_wall_s: st_v.median.as_secs_f64(),
        };
        t2.row(&[
            l.to_string(),
            mask.to_string(),
            cyc_v.to_string(),
            format!("{:.3e}", row.scalar_rate(n)),
            format!("{:.3e}", row.vector_rate(n)),
            format!("{:.2}x", row.speedup()),
        ]);
        rows.push(row);
    }
    println!(
        "simcycles — scalar-reference vs vectorized stepper, PE-steps/s \
         (N = {n}, equal cycles asserted)\n{}",
        t2.to_string()
    );

    // Host cost of one sim-backend head shard (what `sim_max_seq`
    // bounds): a causal L=96 head on the 32-array.
    let mut rng = SplitMix64::new(5);
    let (l, d) = (96usize, 32usize);
    let q = rng.normal_matrix(l, d);
    let k = rng.normal_matrix(l, d);
    let v = rng.normal_matrix(l, d);
    let st = bench_for(Duration::from_secs(2), || {
        head(&mut vec_be, l, d, &q, &k, &v, MaskKind::Causal);
    });
    println!(
        "[bench] sim-backend causal head (L={l}, d={d}, N={n}): median {}",
        fmt_duration(st.median)
    );

    // Machine-readable perf record, diffable across PRs (no serde in
    // the tree — the format is flat enough to hand-roll).
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"simcycles\",\n");
    json.push_str(&format!("  \"array_size\": {n},\n"));
    json.push_str(&format!("  \"smoke\": {},\n", smoke()));
    json.push_str("  \"entries\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"seq\": {}, \"d\": {}, \"mask\": \"{}\", \"cycles\": {}, \
             \"pe_steps\": {:.0}, \"scalar_pe_steps_per_s\": {:.4e}, \
             \"vector_pe_steps_per_s\": {:.4e}, \"scalar_wall_s\": {:.6e}, \
             \"vector_wall_s\": {:.6e}, \"speedup\": {:.3}}}{}\n",
            r.seq,
            r.d,
            r.mask,
            r.cycles,
            r.pe_steps(n),
            r.scalar_rate(n),
            r.vector_rate(n),
            r.scalar_wall_s,
            r.vector_wall_s,
            r.speedup(),
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    json.push_str("  ]\n}\n");
    let path = "BENCH_simcycles.json";
    std::fs::write(path, &json).expect("write bench json");
    println!("[bench] wrote {path}");
}
