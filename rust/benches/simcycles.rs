//! Bench: measured vs modeled cycles of the `backend=sim` serving path
//! (DESIGN.md §8) — the cross-validation sweep that keeps the analytic
//! `perfmodel` honest against the cycle-accurate machine.  For each
//! `(seq_len, mask)` shape the sweep compiles the masked chunk program,
//! runs it on `sim::Machine`, and asserts the measured/modeled ratio
//! stays inside `perfmodel::SIM_MODEL_BAND`; it also times one sim-
//! backend head execution (the per-shard cost `sim_max_seq` guards).

use std::time::Duration;

use fsa::benchutil::{bench_for, fmt_duration, smoke, Table};
use fsa::config::AccelConfig;
use fsa::mask::MaskKind;
use fsa::numerics::SplitMix64;
use fsa::perfmodel::{sim_cross_check, SIM_MODEL_BAND};
use fsa::runtime::SimBackend;

fn main() {
    // A shrunken FSA (32-array) keeps the cycle-accurate runs fast; the
    // bandwidth/clock stay the paper's, so the DMA/compute balance is
    // representative.
    let mut cfg = AccelConfig::builtin("fsa").unwrap();
    cfg.array_size = 32;
    let n = cfg.array_size;

    let seqs: &[usize] = if smoke() { &[64, 96] } else { &[64, 96, 128, 192, 256] };
    let masks = [
        MaskKind::None,
        MaskKind::Causal,
        MaskKind::PaddingKeys { valid: 40 },
    ];

    let mut t = Table::new(&["seq", "mask", "modeled", "measured", "ratio"]);
    for &l in seqs {
        for mask in masks {
            let c = sim_cross_check(&cfg, l, mask, cfg.pwl_segments).unwrap();
            assert!(
                c.within_band(),
                "L={l} {mask}: ratio {:.3} outside {SIM_MODEL_BAND:?}",
                c.ratio
            );
            t.row(&[
                l.to_string(),
                mask.to_string(),
                c.modeled.to_string(),
                c.measured.to_string(),
                format!("{:.3}", c.ratio),
            ]);
        }
    }
    println!(
        "simcycles — measured sim cycles vs perfmodel tile-cycles \
         (band {:?}, N = {n})\n{}",
        SIM_MODEL_BAND,
        t.to_string()
    );

    // Host cost of one sim-backend head shard (what `sim_max_seq`
    // bounds): a causal L=96 head on the 32-array.
    let mut be = SimBackend::new(&cfg);
    let mut rng = SplitMix64::new(5);
    let (l, d) = (96usize, 32usize);
    let q = rng.normal_matrix(l, d);
    let k = rng.normal_matrix(l, d);
    let v = rng.normal_matrix(l, d);
    let st = bench_for(Duration::from_secs(2), || {
        be.execute_head(l, d, &q, &k, &v, MaskKind::Causal).unwrap();
    });
    println!(
        "[bench] sim-backend causal head (L={l}, d={d}, N={n}): median {}",
        fmt_duration(st.median)
    );
}
