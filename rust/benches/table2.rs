//! Bench: regenerate paper Table 2 (end-to-end FlashAttention accuracy on
//! FSA numerics vs exact references) through the PJRT artifacts, plus the
//! small-scale cross-check through the cycle-accurate simulator.
//!
//! Sequence lengths follow the artifacts present: `make artifacts` ships
//! 128..4096; `make artifacts-full` adds the paper's 8192/16384.
use std::path::Path;

use fsa::benchutil::Table;
use fsa::experiments::{sim_accuracy_row, table2_report};
use fsa::runtime::Manifest;

fn main() {
    let dir = Path::new("artifacts");
    let seqs: Vec<usize> = match Manifest::load(dir) {
        Ok(m) => {
            let mut s: Vec<usize> = m
                .entries
                .iter()
                .filter(|e| e.kind == "fsa_attn")
                .map(|e| e.seq_len)
                .collect();
            s.sort_unstable();
            s.dedup();
            s
        }
        Err(e) => {
            eprintln!("artifacts unavailable ({e:#}); run `make artifacts` first");
            return;
        }
    };
    match table2_report(dir, &seqs, 128, 0xF5A) {
        Ok(r) => println!("{r}"),
        Err(e) => eprintln!("table2 failed: {e:#}"),
    }

    // Cross-check: same metric through the cycle-accurate device at
    // simulator-friendly sizes (validates the artifact path end to end).
    let mut t = Table::new(&["n", "seq", "MAE", "RMSE", "MRE"]);
    for (n, seq) in [(16usize, 64usize), (16, 128), (32, 128)] {
        let e = sim_accuracy_row(n, seq, 5).unwrap();
        t.row(&[
            n.to_string(),
            seq.to_string(),
            format!("{:.3e}", e.mae),
            format!("{:.3e}", e.rmse),
            format!("{:.3e}", e.mre),
        ]);
    }
    println!("cycle-simulator cross-check (same metric, small scale):\n{}", t.to_string());
}
