//! Bench: regenerate paper Figure 12 (exp2 PWL MAE/MRE vs segment count,
//! exhaustive over all negative normal fp16 values) in all rounding
//! modes, and time the exhaustive sweep.
use std::time::Duration;

use fsa::benchutil::{bench_for, fmt_duration, observe, Table};
use fsa::experiments::fig12_report;
use fsa::numerics::pwl::{error_sweep_ref, EvalMode};

fn main() {
    println!("{}", fig12_report(&[1, 2, 4, 8, 16, 32, 64]));

    // Mode matrix at 8 segments: quantization choices the paper leaves
    // implicit (EXPERIMENTS.md discusses which one matches).
    let mut t = Table::new(&["mode", "ref", "MAE", "MRE"]);
    for (mode, name) in [
        (EvalMode::Exact, "exact"),
        (EvalMode::F32, "f32"),
        (EvalMode::F16Round, "f16-round"),
        (EvalMode::F16, "f16-flush"),
    ] {
        for (r16, rname) in [(false, "f64"), (true, "f16")] {
            let e = error_sweep_ref(8, mode, r16);
            t.row(&[
                name.into(),
                rname.into(),
                format!("{:.5e}", e.mae),
                format!("{:.5}", e.mre),
            ]);
        }
    }
    println!("mode matrix at 8 segments (paper: MAE 0.00014, MRE 0.02728):\n{}", t.to_string());

    let st = bench_for(Duration::from_millis(300), || {
        observe(error_sweep_ref(8, EvalMode::F16, true));
    });
    println!("[bench] exhaustive fp16 sweep (30720 values): median {}", fmt_duration(st.median));
}
