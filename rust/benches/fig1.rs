//! Bench: regenerate paper Figure 1 (component active-time breakdown on
//! NeuronCore-v2-like and TPUv5e-like machines running FlashAttention),
//! and time the model evaluation itself.
use std::time::Duration;

use fsa::benchutil::{bench_for, fmt_duration, observe};
use fsa::experiments::fig1_report;

fn main() {
    for seq in [2048usize, 8192, 16384] {
        println!("{}", fig1_report(seq));
    }
    let st = bench_for(Duration::from_millis(200), || {
        observe(fig1_report(8192));
    });
    println!("[bench] fig1_report(8192): median {}", fmt_duration(st.median));
}
