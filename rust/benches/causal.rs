//! Bench: causal (masked) attention with the tile-skipping schedule
//! (DESIGN.md §6).
//!
//! Three parts:
//!
//! 1. Model sweep (instant): `perfmodel::fsa_flash_perf_masked` causal
//!    vs square — tile census, total cycles (≈2× fewer for causal) and
//!    FLOPs/s utilization (≈unchanged: FLOPs halve with the cycles).
//! 2. Host-side kernel timing: the reference `flash_pwl_masked` causal
//!    pass vs the square pass at the same L — the tile skip is a real
//!    host-side speedup too, not just a model claim.
//! 3. Live coordinator causal serving on the reference backend,
//!    round-tripping `--mask causal` requests with exact bucket
//!    padding.
//!
//!     cargo bench --bench causal

use std::time::Duration;

use fsa::benchutil::{bench_for, fmt_duration, observe, smoke, Table};
use fsa::config::{AccelConfig, BackendKind, RunConfig};
use fsa::coordinator::request::AttentionRequest;
use fsa::coordinator::Coordinator;
use fsa::mask::MaskKind;
use fsa::numerics::reference::{flash_pwl, flash_pwl_masked, Mat};
use fsa::numerics::SplitMix64;
use fsa::perfmodel::{fsa_flash_perf, fsa_flash_perf_masked};
use fsa::schedule::{masked_tile_counts, Variant};

fn model_sweep() {
    let cfg = AccelConfig::builtin("fsa").unwrap();
    let mut t = Table::new(&[
        "L", "tiles sq", "tiles causal", "cycles sq", "cycles causal", "ratio",
        "util sq %", "util causal %",
    ]);
    let ls: &[usize] = if smoke() { &[2048, 4096] } else { &[2048, 4096, 8192, 16384] };
    for &l in ls {
        let sq = fsa_flash_perf(&cfg, l, 128, Variant::DualPath, 8);
        let ca = fsa_flash_perf_masked(&cfg, l, 128, Variant::DualPath, 8, MaskKind::Causal);
        let (full, partial, skipped) = masked_tile_counts(l, cfg.array_size, MaskKind::Causal);
        let ratio = ca.total_cycles as f64 / sq.total_cycles as f64;
        // The schedule's headline claim, asserted live.
        assert!(ratio < 0.62, "L={l}: causal must halve tile-cycles, got {ratio}");
        t.row(&[
            l.to_string(),
            (full + partial + skipped).to_string(),
            format!("{}", full + partial),
            sq.total_cycles.to_string(),
            ca.total_cycles.to_string(),
            format!("{ratio:.3}"),
            format!("{:.1}", 100.0 * sq.utilization),
            format!("{:.1}", 100.0 * ca.utilization),
        ]);
    }
    println!("-- causal vs square: tile-skipping schedule (perfmodel) --");
    t.print();
}

fn kernel_timing() {
    let (l, d) = if smoke() { (128usize, 32usize) } else { (512usize, 64usize) };
    let tile = 64usize;
    let mut rng = SplitMix64::new(17);
    let q = Mat::new(l, d, rng.normal_matrix(l, d));
    let k = Mat::new(l, d, rng.normal_matrix(l, d));
    let v = Mat::new(l, d, rng.normal_matrix(l, d));

    let sq = bench_for(Duration::from_millis(300), || {
        observe(flash_pwl(&q, &k, &v, tile, tile, 8));
    });
    let ca = bench_for(Duration::from_millis(300), || {
        observe(flash_pwl_masked(&q, &k, &v, tile, tile, 8, MaskKind::Causal));
    });

    let mut t = Table::new(&["host reference kernel", "median", "p95"]);
    t.row(&[format!("square  L={l} d={d}"), fmt_duration(sq.median), fmt_duration(sq.p95)]);
    t.row(&[format!("causal  L={l} d={d}"), fmt_duration(ca.median), fmt_duration(ca.p95)]);
    t.row(&[
        "causal / square".into(),
        format!("{:.2}", ca.median.as_secs_f64() / sq.median.as_secs_f64()),
        String::new(),
    ]);
    println!("\n-- host-side tile skip (reference numerics) --");
    t.print();
}

fn live_coordinator() {
    let (seq, d, heads, kv_heads) = (100usize, 32usize, 4usize, 2usize);
    let bucket = 128usize;
    let coord = Coordinator::start(RunConfig {
        devices: 2,
        max_batch: 8,
        batch_timeout_cycles: 50_000,
        backend: BackendKind::Reference,
        num_heads: heads,
        num_kv_heads: kv_heads,
        mask: MaskKind::Causal,
        ..RunConfig::default()
    })
    .expect("coordinator boots on the reference backend");

    let mut rng = SplitMix64::new(23);
    let q = rng.normal_matrix(heads * seq, d);
    let k = rng.normal_matrix(kv_heads * seq, d);
    let v = rng.normal_matrix(kv_heads * seq, d);
    let base = AttentionRequest::gqa(0, seq, d, heads, kv_heads, q, k, v)
        .with_mask(MaskKind::Causal);
    // Exact bucket padding: the served output's real rows are bitwise
    // the unpadded request's (rust/tests/coordinator_masked.rs pins it;
    // here we just drive the round trip the README advertises).
    let mut id = 0u64;
    let st = bench_for(Duration::from_millis(400), || {
        id += 1;
        let mut req = base.clone().padded(bucket);
        req.id = id;
        let resp = coord.submit_wait(req).expect("submit");
        assert!(resp.output.is_ok());
        assert_eq!(resp.bucket, bucket);
    });

    let mut t = Table::new(&["live causal serving", "value"]);
    t.row(&[
        "request shape".into(),
        format!("L={seq}->bucket {bucket}, d={d}, {heads}q/{kv_heads}kv, causal"),
    ]);
    t.row(&["median round trip".into(), fmt_duration(st.median)]);
    t.row(&["p95 round trip".into(), fmt_duration(st.p95)]);
    println!("\n-- live coordinator (causal, exact bucket padding) --");
    t.print();
    println!("{}", coord.metrics.summary());
    coord.shutdown();
}

fn main() {
    model_sweep();
    kernel_timing();
    live_coordinator();
}
