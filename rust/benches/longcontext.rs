//! Bench: sequence-parallel long-context serving (DESIGN.md §7) —
//! where splitting one sequence across devices beats a single device.
//!
//! Three parts:
//!
//! 1. Model sweep (instant): `perfmodel::seqpar_perf` over L × shard
//!    counts — per-chunk span, merge + communication overhead, and the
//!    speedup vs one device, with the modeled crossover L printed and
//!    asserted (short sequences lose to the overhead, long ones
//!    approach the shard-count-fold reduction).
//! 2. Pool sweep: `seqpar_pool_perf` over devices × shards at a GQA
//!    shape — sequence sharding lifting the `num_kv_heads` device
//!    ceiling that head-sharding alone is stuck at.
//! 3. Live serving: the real coordinator on the reference backend,
//!    identical requests served at seq_shards ∈ {1, 2, 4} on 1 and 2
//!    devices — asserting the gathered outputs are bitwise identical
//!    across device counts (the placement-invariance contract) and
//!    reporting host throughput.
//!
//!     cargo bench --bench longcontext

use std::time::Instant;

use fsa::benchutil::{smoke, Table};
use fsa::config::{AccelConfig, BackendKind, RunConfig};
use fsa::coordinator::request::AttentionRequest;
use fsa::coordinator::Coordinator;
use fsa::mask::MaskKind;
use fsa::numerics::SplitMix64;
use fsa::perfmodel::{seqpar_crossover, seqpar_perf, seqpar_pool_perf};
use fsa::schedule::Variant;

fn model_sweep(cfg: &AccelConfig) {
    let d = 128;
    let ls: &[usize] =
        if smoke() { &[256, 2048, 16384] } else { &[256, 512, 1024, 2048, 4096, 8192, 16384] };
    for mask in [MaskKind::None, MaskKind::Causal] {
        let mut t = Table::new(&[
            "L", "shards", "chunk max kc", "merge kc", "comm kc", "1-dev kc", "speedup",
        ]);
        for &l in ls {
            for shards in [2usize, 4, 8] {
                let p = seqpar_perf(cfg, l, d, shards, Variant::DualPath, 8, mask);
                t.row(&[
                    l.to_string(),
                    shards.to_string(),
                    format!("{:.1}", p.chunk_cycles_max as f64 / 1e3),
                    format!("{:.1}", p.merge_cycles as f64 / 1e3),
                    format!("{:.1}", p.comm_cycles as f64 / 1e3),
                    format!("{:.1}", p.single_device_cycles as f64 / 1e3),
                    format!("{:.2}x", p.speedup),
                ]);
            }
        }
        println!("\n-- sequence-parallel model (d=128, mask {mask}) --");
        t.print();
    }
    let sweep = [128usize, 256, 512, 1024, 2048, 4096, 8192, 16384];
    let crossover =
        seqpar_crossover(cfg, d, 4, Variant::DualPath, 8, MaskKind::None, &sweep)
            .expect("4-way sharding must win somewhere");
    println!("\nmodeled crossover: 4-way sequence sharding wins from L = {crossover}");
    assert!(
        seqpar_perf(cfg, 16384, d, 4, Variant::DualPath, 8, MaskKind::None).speedup > 2.0,
        "long-context speedup must be substantial"
    );
}

fn pool_sweep(cfg: &AccelConfig) {
    let (l, d, heads, kv) = (16384usize, 128usize, 8usize, 2usize);
    let mut t = Table::new(&["devices", "seq shards", "devices used", "latency kc", "util %"]);
    for &devices in &[2usize, 4, 8] {
        for &shards in &[1usize, 2, 4] {
            let p = seqpar_pool_perf(
                cfg, l, d, heads, kv, devices, shards, Variant::DualPath, 8, MaskKind::None,
            );
            t.row(&[
                devices.to_string(),
                shards.to_string(),
                p.devices_used.to_string(),
                format!("{:.0}", p.critical_path_cycles as f64 / 1e3),
                format!("{:.1}", 100.0 * p.utilization),
            ]);
        }
    }
    println!("\n-- pool model: L=16384 8q/2kv — sequence shards lift the KV-head ceiling --");
    t.print();
}

/// Serve `n_req` identical requests and return the gathered outputs
/// (plus host tokens/s).  Outputs must not depend on `devices` — the
/// bitwise placement-invariance contract asserted by the caller.
fn live_run(
    devices: usize,
    seq_shards: usize,
    seq: usize,
    n_req: usize,
    mask: MaskKind,
) -> (Vec<Vec<f32>>, f64) {
    let (d, heads, kv_heads) = (32usize, 4usize, 2usize);
    let coord = Coordinator::start(RunConfig {
        devices,
        backend: BackendKind::Reference,
        num_heads: heads,
        num_kv_heads: kv_heads,
        seq_shards,
        ..RunConfig::default()
    })
    .expect("coordinator boots on the reference backend");

    // Same seed for every configuration: identical request tensors.
    let mut rng = SplitMix64::new(42);
    let t0 = Instant::now();
    let mut pending = Vec::new();
    for id in 0..n_req as u64 {
        let q = rng.normal_matrix(heads * seq, d);
        let k = rng.normal_matrix(kv_heads * seq, d);
        let v = rng.normal_matrix(kv_heads * seq, d);
        pending.push(
            coord
                .submit(
                    AttentionRequest::gqa(id, seq, d, heads, kv_heads, q, k, v).with_mask(mask),
                )
                .expect("submit"),
        );
    }
    let outs: Vec<Vec<f32>> = pending
        .into_iter()
        .map(|rx| rx.recv().expect("response").output.expect("request served"))
        .collect();
    let wall = t0.elapsed();
    coord.shutdown();
    (outs, n_req as f64 * seq as f64 / wall.as_secs_f64())
}

fn live_sweep() {
    let (seq, n_req) = if smoke() { (64, 2) } else { (256, 8) };
    let mut t = Table::new(&["mask", "seq shards", "1-dev tok/s", "2-dev tok/s", "bitwise"]);
    for mask in [MaskKind::None, MaskKind::Causal] {
        for shards in [1usize, 2, 4] {
            let (a, tps1) = live_run(1, shards, seq, n_req, mask);
            let (b, tps2) = live_run(2, shards, seq, n_req, mask);
            assert_eq!(
                a, b,
                "mask {mask} shards {shards}: output depends on device count"
            );
            t.row(&[
                mask.to_string(),
                shards.to_string(),
                format!("{tps1:.0}"),
                format!("{tps2:.0}"),
                "ok".into(),
            ]);
        }
    }
    println!("\n-- live serving: outputs bitwise-invariant to pool size ({n_req} reqs, L={seq}) --");
    t.print();
}

fn main() {
    let cfg = AccelConfig::builtin("fsa").unwrap();
    model_sweep(&cfg);
    pool_sweep(&cfg);
    live_sweep();
}
