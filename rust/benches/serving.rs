//! Bench: the serving-path perf trajectory (DESIGN.md §9) — a live
//! coordinator pool under open-loop Poisson arrivals, across the six
//! serving modes the repo cares about:
//!
//! * `stateless_mix` — mixed masks/shapes on the reference pool;
//! * `decode` — sessions stepped in lockstep (prefill → decode → close),
//!   so TTFT (prefill latency) and TPOT (decode latency) are populated;
//! * `sim_attrib` — the same traffic shape on `backend=sim`, harvesting
//!   the per-instruction-class cycle attribution and asserting the
//!   exact-sum contract across every response;
//! * `seqpar` — `seq_shards = 2` chunked serving with gather-time
//!   merges;
//! * `continuous` — pipelined multi-session decode rounds under tight
//!   token budgets, so the scheduler's continuous-batching waves (and
//!   the `batch_occupancy` / wave-mix counters) are exercised
//!   (DESIGN.md §10);
//! * `prefix` — a shared-system-prompt session mix with `prefix_cache
//!   = on`, reporting the admission hit rate and the modeled
//!   saved-prefill-cycles of resumed prefills (DESIGN.md §11).
//!
//! Every scenario embeds its pool's full [`MetricsSnapshot`] JSON
//! (counters, latency p50/p95/p99, TTFT/TPOT, queue depth, per-backend
//! dispatch split, KV gauges) into `BENCH_serving.json` — the same
//! schema `fsa serve --metrics-json` writes — so the serving trajectory
//! is diffable across PRs; see EXPERIMENTS.md §Perf log.  `make
//! bench-json` runs this target (and `simcycles`); the emitted document
//! is parsed back before it is written, so a malformed record fails the
//! bench rather than the reader.

use std::time::{Duration, Instant};

use fsa::benchutil::{fmt_duration, smoke, Table};
use fsa::config::{BackendKind, RunConfig};
use fsa::coordinator::request::{AttentionRequest, AttentionResponse, OpKind};
use fsa::coordinator::Coordinator;
use fsa::mask::MaskKind;
use fsa::numerics::SplitMix64;
use fsa::sim::CycleBreakdown;
use fsa::telemetry::json::{parse, Json};

fn cfg(backend: BackendKind, devices: usize, seq_shards: usize) -> RunConfig {
    RunConfig {
        devices,
        max_batch: 8,
        batch_timeout_cycles: 50_000,
        // Deeper than any scenario's total request count, so open-loop
        // submission never trips ingress backpressure mid-bench.
        queue_depth: 256,
        backend,
        num_heads: 4,
        num_kv_heads: 2,
        seq_shards,
        sim_max_seq: 256,
        array_size: 32,
        ..RunConfig::default()
    }
}

fn gqa_req(seed: u64, id: u64, seq: usize, d: usize, heads: usize, kv: usize) -> AttentionRequest {
    let mut rng = SplitMix64::new(seed);
    AttentionRequest::gqa(
        id,
        seq,
        d,
        heads,
        kv,
        rng.normal_matrix(heads * seq, d),
        rng.normal_matrix(kv * seq, d),
        rng.normal_matrix(kv * seq, d),
    )
}

/// One exponential inter-arrival gap (`-ln(1-u) · mean`, u ∈ [0, 1)),
/// i.e. Poisson arrivals at rate `1/mean`.
fn poisson_gap(rng: &mut SplitMix64, mean: Duration) -> Duration {
    Duration::from_secs_f64(-(1.0 - rng.next_f64()).ln() * mean.as_secs_f64())
}

/// Submit every request at its Poisson arrival time (open loop — the
/// submitter never waits for responses), then drain all of them.
fn run_open_loop(
    coord: &Coordinator,
    reqs: Vec<AttentionRequest>,
    mean_gap: Duration,
    seed: u64,
) -> (Duration, Vec<AttentionResponse>) {
    let mut rng = SplitMix64::new(seed);
    let start = Instant::now();
    let mut due = Duration::ZERO;
    let mut rxs = Vec::with_capacity(reqs.len());
    for req in reqs {
        due += poisson_gap(&mut rng, mean_gap);
        if let Some(wait) = due.checked_sub(start.elapsed()) {
            if !wait.is_zero() {
                std::thread::sleep(wait);
            }
        }
        rxs.push(coord.submit(req).expect("ingress accepts (queue_depth sized for the bench)"));
    }
    let resps: Vec<AttentionResponse> =
        rxs.into_iter().map(|rx| rx.recv().expect("response arrives")).collect();
    (start.elapsed(), resps)
}

/// Freeze a pool's metrics into the scenario record: request/throughput
/// figures, simulated-device occupancy, and the full snapshot JSON.
fn scenario_json(
    name: &str,
    coord: &Coordinator,
    rc: &RunConfig,
    wall: Duration,
    requests: usize,
    ok: usize,
) -> Json {
    let snap = coord.metrics.snapshot();
    // Simulated device time (cycles at the configured clock) over host
    // wall time × devices: how busy the simulated fleet was, in
    // simulated seconds per host second — a trajectory statistic, not a
    // physical utilization.
    let device_s = snap.counter("device_cycles").unwrap_or(0) as f64 / (rc.freq_ghz * 1e9);
    let wall_s = wall.as_secs_f64();
    let mut j = Json::obj();
    j.set("name", Json::str(name))
        .set("requests", Json::u64(requests as u64))
        .set("ok", Json::u64(ok as u64))
        .set("wall_s", Json::Num(wall_s))
        .set("throughput_rps", Json::Num(requests as f64 / wall_s))
        .set("devices", Json::u64(rc.devices as u64))
        .set("sim_device_time_s", Json::Num(device_s))
        .set("sim_occupancy", Json::Num(device_s / (wall_s * rc.devices as f64)))
        .set("metrics", snap.to_json());
    j
}

fn table_row(t: &mut Table, name: &str, coord: &Coordinator, requests: usize, wall: Duration) {
    let snap = coord.metrics.snapshot();
    let ns = |v: u64| fmt_duration(Duration::from_nanos(v));
    t.row(&[
        name.to_string(),
        requests.to_string(),
        fmt_duration(wall),
        format!("{:.0}", requests as f64 / wall.as_secs_f64()),
        ns(snap.latency_ns.p50),
        ns(snap.latency_ns.p95),
        ns(snap.latency_ns.p99),
        ns(snap.kind(OpKind::Prefill).p50),
        ns(snap.kind(OpKind::Decode).p50),
    ]);
}

/// Mixed stateless traffic (unmasked / causal / padded keys over a
/// sweep of shapes) on the reference pool.
fn stateless_mix(t: &mut Table) -> Json {
    let rc = cfg(BackendKind::Reference, 2, 1);
    let coord = Coordinator::start(rc.clone()).unwrap();
    let n = if smoke() { 12 } else { 96 };
    let seqs = [32usize, 48, 64, 96];
    let reqs: Vec<AttentionRequest> = (0..n)
        .map(|i| {
            let seq = seqs[i % seqs.len()];
            let mask = match i % 3 {
                0 => MaskKind::None,
                1 => MaskKind::Causal,
                _ => MaskKind::PaddingKeys { valid: seq * 5 / 8 },
            };
            gqa_req(9000 + i as u64, i as u64, seq, 32, 4, 2).with_mask(mask)
        })
        .collect();
    let gap = Duration::from_micros(if smoke() { 50 } else { 150 });
    let (wall, resps) = run_open_loop(&coord, reqs, gap, 11);
    let ok = resps.iter().filter(|r| r.output.is_ok()).count();
    assert_eq!(ok, n, "stateless_mix must serve every request");
    let j = scenario_json("stateless_mix", &coord, &rc, wall, n, ok);
    table_row(t, "stateless_mix", &coord, n, wall);
    coord.shutdown();
    j
}

/// Decode-phase serving: sessions prefilled, then stepped in lockstep
/// (closed loop — decode steps are causally ordered per session), then
/// closed.  Populates the TTFT and TPOT histograms.
fn decode_scenario(t: &mut Table) -> Json {
    let rc = cfg(BackendKind::Reference, 2, 1);
    let coord = Coordinator::start(rc.clone()).unwrap();
    let (sessions, steps) = if smoke() { (2usize, 4usize) } else { (6, 24) };
    let (seq, d, heads, kv) = (64usize, 32usize, 2usize, 1usize);
    let mut rng = SplitMix64::new(21);
    let start = Instant::now();
    for s in 0..sessions as u64 {
        let prefill = AttentionRequest::prefill(
            s,
            s,
            seq,
            d,
            heads,
            kv,
            rng.normal_matrix(heads * seq, d),
            rng.normal_matrix(kv * seq, d),
            rng.normal_matrix(kv * seq, d),
        )
        .with_mask(MaskKind::Causal);
        coord.submit_wait(prefill).unwrap().output.expect("prefill succeeds");
    }
    let mut id = 1000u64;
    for step in 0..steps as u64 {
        for s in 0..sessions as u64 {
            id += 1;
            let dec = AttentionRequest::decode(
                id,
                s,
                step,
                d,
                heads,
                kv,
                rng.normal_matrix(heads, d),
                rng.normal_matrix(kv, d),
                rng.normal_matrix(kv, d),
            );
            coord.submit_wait(dec).unwrap().output.expect("decode step succeeds");
        }
    }
    for s in 0..sessions as u64 {
        id += 1;
        coord.submit_wait(AttentionRequest::close(id, s)).unwrap();
    }
    let wall = start.elapsed();
    let requests = sessions * (steps + 2);
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.kind(OpKind::Prefill).count, sessions as u64, "one TTFT sample per session");
    assert_eq!(
        snap.kind(OpKind::Decode).count,
        (sessions * steps) as u64,
        "one TPOT sample per decode step"
    );
    let j = scenario_json("decode", &coord, &rc, wall, requests, requests);
    table_row(t, "decode", &coord, requests, wall);
    coord.shutdown();
    j
}

/// The attribution scenario: `backend=sim` (32-wide array) serving, with
/// every response's per-instruction-class cycle breakdown harvested and
/// the exact-sum contract asserted across the whole run.
fn sim_attrib(t: &mut Table) -> Json {
    let rc = cfg(BackendKind::Sim, 2, 1);
    let coord = Coordinator::start(rc.clone()).unwrap();
    let n = if smoke() { 4 } else { 12 };
    let seqs = [48usize, 64, 96];
    let reqs: Vec<AttentionRequest> = (0..n)
        .map(|i| {
            let mask = if i % 2 == 0 { MaskKind::None } else { MaskKind::Causal };
            gqa_req(5000 + i as u64, i as u64, seqs[i % seqs.len()], 16, 2, 1).with_mask(mask)
        })
        .collect();
    let (wall, resps) = run_open_loop(&coord, reqs, Duration::from_micros(200), 13);
    let mut agg = CycleBreakdown::default();
    let mut cycles = 0u64;
    for r in &resps {
        assert!(r.output.is_ok(), "sim_attrib must serve every request");
        assert_eq!(r.stats.measured_shards, r.shards, "sim prices from measured cycles");
        let bd = r.stats.cycle_breakdown.expect("sim responses carry attribution");
        assert_eq!(bd.total(), r.device_cycles, "attribution must sum exactly ({bd:?})");
        agg.add(&bd);
        cycles += r.device_cycles;
    }
    assert_eq!(agg.total(), cycles, "aggregated attribution must sum exactly");
    let mut attrib = Json::obj();
    attrib
        .set("score", Json::u64(agg.score))
        .set("exp", Json::u64(agg.exp))
        .set("rowsum", Json::u64(agg.rowsum))
        .set("pv", Json::u64(agg.pv))
        .set("mask_wave", Json::u64(agg.mask_wave))
        .set("dma", Json::u64(agg.dma))
        .set("stall", Json::u64(agg.stall))
        .set("recompute", Json::u64(agg.recompute))
        .set("total", Json::u64(agg.total()));
    let mut j = scenario_json("sim_attrib", &coord, &rc, wall, n, n);
    j.set("cycle_attribution", attrib);
    table_row(t, "sim_attrib", &coord, n, wall);
    coord.shutdown();
    j
}

/// Sequence-parallel serving (`seq_shards = 2`): chunked shards with
/// exact partial-softmax merges at gather (DESIGN.md §7).
fn seqpar(t: &mut Table) -> Json {
    let rc = cfg(BackendKind::Reference, 3, 2);
    let coord = Coordinator::start(rc.clone()).unwrap();
    let n = if smoke() { 4 } else { 24 };
    let reqs: Vec<AttentionRequest> = (0..n)
        .map(|i| {
            let mask = if i % 2 == 0 { MaskKind::None } else { MaskKind::Causal };
            gqa_req(7000 + i as u64, i as u64, 64, 32, 4, 2).with_mask(mask)
        })
        .collect();
    let gap = Duration::from_micros(if smoke() { 50 } else { 150 });
    let (wall, resps) = run_open_loop(&coord, reqs, gap, 17);
    for r in &resps {
        assert!(r.output.is_ok(), "seqpar must serve every request");
        assert_eq!(r.stats.seq_chunks, 2, "requests must be sequence-sharded");
    }
    let snap = coord.metrics.snapshot();
    assert!(snap.counter("merge_steps").unwrap_or(0) > 0, "gather must merge partials");
    let j = scenario_json("seqpar", &coord, &rc, wall, n, n);
    table_row(t, "seqpar", &coord, n, wall);
    coord.shutdown();
    j
}

/// Continuous batching (DESIGN.md §10): tight token budgets + a long
/// group timeout, with each decode round submitted pipelined across
/// all sessions so steps of many live sessions share dispatch waves.
/// Asserts the scheduler-counter reconciliation invariant and that at
/// least one wave actually mixed sessions — the continuous payoff the
/// `batch_occupancy` / wave-mix telemetry in `BENCH_serving.json`
/// tracks across PRs.
fn continuous(t: &mut Table) -> Json {
    let mut rc = cfg(BackendKind::Reference, 2, 1);
    // ~1.3 ms at 1.5 GHz: long enough for a round's steps to assemble
    // into shared waves, short enough to keep the bench quick.
    rc.batch_timeout_cycles = 2_000_000;
    rc.max_batch_prefill_tokens = 128; // two seq-64 prefills per wave
    rc.max_batch_total_tokens = 4096;
    rc.waiting_served_ratio = 1.2;
    let coord = Coordinator::start(rc.clone()).unwrap();
    let (sessions, steps) = if smoke() { (2usize, 4usize) } else { (4, 16) };
    let (seq, d, heads, kv) = (64usize, 32usize, 2usize, 1usize);
    let mut rng = SplitMix64::new(31);
    let start = Instant::now();
    // Prefills pipelined: the third and fourth defer behind the
    // 128-token wave budget while the first two open.
    let rxs: Vec<_> = (0..sessions as u64)
        .map(|s| {
            let prefill = AttentionRequest::prefill(
                s,
                s,
                seq,
                d,
                heads,
                kv,
                rng.normal_matrix(heads * seq, d),
                rng.normal_matrix(kv * seq, d),
                rng.normal_matrix(kv * seq, d),
            )
            .with_mask(MaskKind::Causal);
            coord.submit(prefill).expect("ingress accepts")
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap().output.expect("prefill succeeds");
    }
    let mut id = 1000u64;
    for step in 0..steps as u64 {
        // One round: every live session's step in flight at once — the
        // shards the scheduler batches into shared decode waves.
        let rxs: Vec<_> = (0..sessions as u64)
            .map(|s| {
                id += 1;
                let dec = AttentionRequest::decode(
                    id,
                    s,
                    step,
                    d,
                    heads,
                    kv,
                    rng.normal_matrix(heads, d),
                    rng.normal_matrix(kv, d),
                    rng.normal_matrix(kv, d),
                );
                coord.submit(dec).expect("ingress accepts")
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap().output.expect("decode step succeeds");
        }
    }
    for s in 0..sessions as u64 {
        id += 1;
        coord.submit_wait(AttentionRequest::close(id, s)).unwrap();
    }
    let wall = start.elapsed();
    let requests = sessions * (steps + 2);
    let o = std::sync::atomic::Ordering::Relaxed;
    let m = &coord.metrics;
    assert_eq!(m.sched_queued.load(o), requests as u64, "scheduler saw every request");
    assert_eq!(
        m.sched_admitted.load(o),
        m.sched_queued.load(o) - m.sched_rejected.load(o),
        "admitted = queued - rejected"
    );
    assert_eq!(m.sched_rejected.load(o), sessions as u64, "closes are answered inline");
    assert!(
        m.multi_session_decode_waves.load(o) >= 1,
        "continuous serving must batch decode steps of different sessions"
    );
    let j = scenario_json("continuous", &coord, &rc, wall, requests, requests);
    table_row(t, "continuous", &coord, requests, wall);
    coord.shutdown();
    j
}

/// Shared-system-prompt serving with `prefix_cache = on` (DESIGN.md
/// §11): every session's prompt opens with the same 48-token system
/// prefix (three whole KV pages), so each prefill after the first
/// matches at admission, prices only its uncovered suffix, and resumes
/// on the devices from the shared refcounted pages.  The scenario
/// record carries the admission hit rate and the modeled
/// saved-prefill-cycles alongside the usual snapshot.
fn prefix(t: &mut Table) -> Json {
    let mut rc = cfg(BackendKind::Reference, 2, 1);
    rc.prefix_cache = true;
    let coord = Coordinator::start(rc.clone()).unwrap();
    let (sessions, steps) = if smoke() { (3usize, 2usize) } else { (12, 8) };
    let (seq, d, heads, kv) = (64usize, 32usize, 4usize, 2usize);
    let sys = 48usize; // shared system prompt: three kv_page_size=16 pages
    let mut rng = SplitMix64::new(41);
    let k_base = rng.normal_matrix(kv * seq, d);
    let v_base = rng.normal_matrix(kv * seq, d);
    // Overlay the shared system prefix onto a session's fresh K or V
    // (head-major `(kv_heads, seq, d)` layout).
    let share = |base: &[f32], mut fresh: Vec<f32>| -> Vec<f32> {
        let stride = seq * d;
        for h in 0..kv {
            fresh[h * stride..h * stride + sys * d]
                .copy_from_slice(&base[h * stride..h * stride + sys * d]);
        }
        fresh
    };
    let start = Instant::now();
    // Closed-loop prefills: each session's prompt is indexed before the
    // next arrives, so every prefill after the first finds the shared
    // pages already cached.
    for s in 0..sessions as u64 {
        let req = AttentionRequest::prefill(
            s,
            s,
            seq,
            d,
            heads,
            kv,
            rng.normal_matrix(heads * seq, d),
            share(&k_base, rng.normal_matrix(kv * seq, d)),
            share(&v_base, rng.normal_matrix(kv * seq, d)),
        )
        .with_mask(MaskKind::Causal);
        let resp = coord.submit_wait(req).unwrap();
        resp.output.expect("prefill succeeds");
        if s > 0 {
            assert_eq!(
                resp.stats.prefix_reused_tokens, sys,
                "warm prefill must resume past the shared system prompt"
            );
        }
    }
    let mut id = 1000u64;
    for step in 0..steps as u64 {
        for s in 0..sessions as u64 {
            id += 1;
            let dec = AttentionRequest::decode(
                id,
                s,
                step,
                d,
                heads,
                kv,
                rng.normal_matrix(heads, d),
                rng.normal_matrix(kv, d),
                rng.normal_matrix(kv, d),
            );
            coord.submit_wait(dec).unwrap().output.expect("decode step succeeds");
        }
    }
    for s in 0..sessions as u64 {
        id += 1;
        coord.submit_wait(AttentionRequest::close(id, s)).unwrap();
    }
    let wall = start.elapsed();
    let requests = sessions * (steps + 2);
    let o = std::sync::atomic::Ordering::Relaxed;
    let hits = coord.metrics.prefix_hits.load(o);
    let misses = coord.metrics.prefix_misses.load(o);
    let saved = coord.metrics.saved_prefill_cycles.load(o);
    assert_eq!(misses, 1, "only the first (donor) prefill may miss");
    assert_eq!(hits, sessions as u64 - 1, "every later prefill must hit");
    assert!(saved > 0, "resumed prefills must save modeled device cycles");
    let mut pc = Json::obj();
    pc.set("hits", Json::u64(hits))
        .set("misses", Json::u64(misses))
        .set("hit_rate", Json::Num(hits as f64 / (hits + misses) as f64))
        .set("attached_pages", Json::u64(coord.metrics.prefix_attached_pages.load(o)))
        .set("saved_prefill_cycles", Json::u64(saved));
    let mut j = scenario_json("prefix", &coord, &rc, wall, requests, requests);
    j.set("prefix_cache", pc);
    table_row(t, "prefix", &coord, requests, wall);
    coord.shutdown();
    j
}

fn main() {
    let mut t = Table::new(&[
        "scenario", "reqs", "wall", "rps", "p50", "p95", "p99", "TTFT p50", "TPOT p50",
    ]);
    let scenarios = vec![
        stateless_mix(&mut t),
        decode_scenario(&mut t),
        sim_attrib(&mut t),
        seqpar(&mut t),
        continuous(&mut t),
        prefix(&mut t),
    ];
    println!(
        "serving — coordinator pools under Poisson/lockstep load \
         (latencies host-side, smoke = {})\n{}",
        smoke(),
        t.to_string()
    );

    let mut root = Json::obj();
    root.set("bench", Json::str("serving"))
        .set("smoke", Json::Bool(smoke()))
        .set("scenarios", Json::Arr(scenarios));
    let text = root.pretty();
    // The record must be readable by the CI gate (python3 json.load)
    // and our own parser; fail here, not in the reader.
    parse(&text).expect("emitted BENCH_serving.json parses back");
    let path = "BENCH_serving.json";
    std::fs::write(path, &text).expect("write bench json");
    println!("[bench] wrote {path}");
}
