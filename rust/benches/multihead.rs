//! Bench: multi-head / GQA head-sharded serving across the device pool.
//!
//! Two parts:
//!
//! 1. Model sweep (instant): whole-operator FLOPs/s utilization from
//!    `perfmodel::multi_head_perf` across head counts and pool sizes —
//!    the multi-head analogue of the Fig.-11 single-head curves,
//!    showing perfect rounds vs ragged-tail degradation.
//! 2. Live coordinator throughput: boots the real coordinator on the
//!    reference backend (no artifacts needed) and measures host-side
//!    request throughput of GQA serving at a small shape, where
//!    batching/routing/gather overhead — not numerics — dominates.
//!
//!     cargo bench --bench multihead

use std::time::Duration;

use fsa::benchutil::{bench_for, fmt_duration, Table};
use fsa::config::{AccelConfig, BackendKind, RunConfig};
use fsa::coordinator::request::AttentionRequest;
use fsa::coordinator::Coordinator;
use fsa::numerics::SplitMix64;
use fsa::perfmodel::multi_head_perf;
use fsa::schedule::Variant;

fn model_sweep() {
    let cfg = AccelConfig::builtin("fsa").unwrap();
    let mut t = Table::new(&[
        "L", "heads", "kv", "pool", "used", "rounds", "critical cycles", "pool util %",
    ]);
    for &(l, heads, kv) in &[(2048usize, 8usize, 8usize), (2048, 8, 2), (4096, 32, 8), (4096, 40, 8)] {
        for &devices in &[1usize, 2, 4, 8] {
            let p = multi_head_perf(&cfg, l, 128, heads, kv, devices, Variant::DualPath, 8);
            t.row(&[
                l.to_string(),
                heads.to_string(),
                kv.to_string(),
                devices.to_string(),
                p.devices_used.to_string(),
                p.rounds.to_string(),
                p.critical_path_cycles.to_string(),
                format!("{:.1}", 100.0 * p.utilization),
            ]);
        }
    }
    println!("-- whole-operator utilization model (multi-head Fig.-11 analogue) --");
    t.print();
}

fn live_coordinator() {
    let (seq, d, heads, kv_heads) = (64usize, 64usize, 8usize, 2usize);
    let coord = Coordinator::start(RunConfig {
        devices: 4,
        max_batch: 8,
        batch_timeout_cycles: 50_000,
        queue_depth: 1024,
        artifacts_dir: "artifacts".into(),
        backend: BackendKind::Reference,
        num_heads: heads,
        num_kv_heads: kv_heads,
        ..RunConfig::default()
    })
    .expect("coordinator boots on the reference backend");

    let mut rng = SplitMix64::new(99);
    let q = rng.normal_matrix(heads * seq, d);
    let k = rng.normal_matrix(kv_heads * seq, d);
    let v = rng.normal_matrix(kv_heads * seq, d);
    let mut id = 0u64;
    let st = bench_for(Duration::from_millis(400), || {
        id += 1;
        let resp = coord
            .submit_wait(AttentionRequest::gqa(
                id, seq, d, heads, kv_heads,
                q.clone(), k.clone(), v.clone(),
            ))
            .expect("submit");
        assert!(resp.output.is_ok());
        assert_eq!(resp.shards, heads);
    });

    let mut t = Table::new(&["live GQA serving", "value"]);
    t.row(&["request shape".into(), format!("L={seq} d={d} {heads}q/{kv_heads}kv heads")]);
    t.row(&["median round trip".into(), fmt_duration(st.median)]);
    t.row(&["p95 round trip".into(), fmt_duration(st.p95)]);
    t.row(&[
        "head shards/s (median)".into(),
        format!("{:.0}", heads as f64 / st.median.as_secs_f64()),
    ]);
    println!("\n-- live coordinator (reference backend, 4 devices) --");
    t.print();
    println!("{}", coord.metrics.summary());
    coord.shutdown();
}

fn main() {
    model_sweep();
    live_coordinator();
}
