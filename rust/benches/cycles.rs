//! Bench: validate the paper's §3.5/§8.2 cycle counts against the
//! cycle-accurate simulator (5N+10 inner loop, 6N+10 single-path, 8N-2
//! naive two-matmul) and time the simulator itself.
use std::time::Duration;

use fsa::benchutil::{bench_for, fmt_duration};
use fsa::experiments::cycles_report;

fn main() {
    println!("{}", cycles_report(&[4, 8, 16, 32, 64]));
    let st = bench_for(Duration::from_secs(2), || {
        fsa::experiments::sim_accuracy_row(16, 32, 1).unwrap();
    });
    println!(
        "[bench] full 16x16 device run (2x2 tiles, schedule+execute+verify): median {}",
        fmt_duration(st.median)
    );
}
