"""L2 — JAX attention entry points lowered to the Rust runtime's artifacts.

Each entry point is a pure function over concrete shapes; ``aot.py`` lowers
them once to HLO *text* and the Rust ``fsa::runtime`` executes them through
PJRT on the request path.  Everything here calls the L1 Pallas kernel (or
one of its oracles) — no other compute library exists at runtime.

Entry points:

* ``fsa_attn``     — single-head FlashAttention with FSA numerics (the
                     device-accurate path; what the serving examples run).
* ``flash_exact``  — op-order-identical exact-exp2 twin (reference used by
                     Table 2 at sequence lengths where dense SDPA would
                     need O(L^2) memory).
* ``sdpa``         — dense fp32 reference (small/medium L).
* ``fsa_mha``      — multi-head (vmap) variant, plus ``mha_proj``: a full
                     attention block with QKVO projections, demonstrating
                     the kernel composing into a model-level graph.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.fsa_attention import fsa_attention, fsa_attention_mha


def fsa_attn(q, k, v, br: int = 128, bc: int = 128, segments: int = 8):
    return (fsa_attention(q, k, v, br=br, bc=bc, segments=segments),)


def flash_exact(q, k, v, br: int = 128, bc: int = 128):
    return (ref.flash_exact(q, k, v, br=br, bc=bc),)


def sdpa(q, k, v):
    return (ref.sdpa(q, k, v),)


def fsa_mha(q, k, v, br: int = 128, bc: int = 128, segments: int = 8):
    return (fsa_attention_mha(q, k, v, br=br, bc=bc, segments=segments),)


def mha_proj(x, wq, wk, wv, wo, heads: int, br: int = 128, bc: int = 128,
             segments: int = 8):
    """Full multi-head attention block: projections around the FSA kernel.

    ``x``: (L, D); ``wq/wk/wv/wo``: (D, D).  D must equal heads * d_head.
    Projections run in the activation dtype; attention per head on FSA
    numerics; output projection back to (L, D).
    """
    L, D = x.shape
    d = D // heads
    if d * heads != D:
        raise ValueError(f"D={D} not divisible by heads={heads}")

    def split(y):  # (L, D) -> (H, L, d)
        return jnp.transpose(y.reshape(L, heads, d), (1, 0, 2))

    q = split(x @ wq)
    k = split(x @ wk)
    v = split(x @ wv)
    o = fsa_attention_mha(q, k, v, br=br, bc=bc, segments=segments)
    o = jnp.transpose(o, (1, 0, 2)).reshape(L, D)
    return (o @ wo,)
