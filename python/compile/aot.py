"""AOT compile path: lower every L2 entry point to HLO text artifacts.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under ``artifacts/``):

* ``<name>.hlo.txt``      — one per entry point / shape combination
* ``manifest.txt``        — machine-readable index consumed by
                            ``fsa::runtime::Manifest`` (whitespace table)
* ``pwl_coeffs_<S>.txt``  — golden PWL coefficient tables cross-checked by
                            ``fsa::numerics::pwl`` tests
* ``.stamp``              — build stamp for the Makefile

Usage: ``python -m compile.aot --out ../artifacts [--full]``
"""

from __future__ import annotations

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.pwl import coefficients

HEAD_DIM = 128          # paper evaluation: d = 128 throughout
DEFAULT_SEQ = [128, 512, 2048, 4096]
FULL_SEQ = [8192, 16384]
SDPA_MAX_SEQ = 4096     # dense L x L fp32 reference beyond this is wasteful
COEFF_SEGMENTS = [1, 2, 4, 8, 16, 32, 64]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entries(full: bool):
    """(name, fn, arg_specs, manifest fields) for every artifact."""
    seqs = DEFAULT_SEQ + (FULL_SEQ if full else [])
    d = HEAD_DIM
    f16 = jnp.float16
    entries = []
    for L in seqs:
        qkv = [_spec((L, d), f16)] * 3
        entries.append((
            f"fsa_attn_L{L}_d{d}", model.fsa_attn, qkv,
            dict(kind="fsa_attn", dtype="f16", L=L, d=d, heads=1, br=128,
                 bc=128, segments=8),
        ))
        entries.append((
            f"flash_exact_L{L}_d{d}", model.flash_exact, qkv,
            dict(kind="flash_exact", dtype="f16", L=L, d=d, heads=1, br=128,
                 bc=128, segments=0),
        ))
        if L <= SDPA_MAX_SEQ:
            entries.append((
                f"sdpa_L{L}_d{d}", model.sdpa, qkv,
                dict(kind="sdpa", dtype="f16", L=L, d=d, heads=1, br=0,
                     bc=0, segments=0),
            ))
    # Multi-head + full projection block (model-level composition).
    H, Lm = 4, 512
    mqkv = [_spec((H, Lm, d), f16)] * 3
    entries.append((
        f"fsa_mha_h{H}_L{Lm}_d{d}", model.fsa_mha, mqkv,
        dict(kind="fsa_mha", dtype="f16", L=Lm, d=d, heads=H, br=128,
             bc=128, segments=8),
    ))
    D = H * d
    proj = [_spec((Lm, D), f16)] + [_spec((D, D), f16)] * 4
    entries.append((
        f"mha_proj_h{H}_L{Lm}_D{D}",
        functools.partial(model.mha_proj, heads=H), proj,
        dict(kind="mha_proj", dtype="f16", L=Lm, d=d, heads=H, br=128,
             bc=128, segments=8),
    ))
    return entries


def write_coeff_tables(out_dir: str) -> None:
    for s in COEFF_SEGMENTS:
        slopes, intercepts = coefficients(s)
        path = os.path.join(out_dir, f"pwl_coeffs_{s}.txt")
        with open(path, "w") as f:
            f.write(f"# k slope intercept (segments={s})\n")
            for k in range(s):
                f.write(f"{k} {slopes[k]:.17g} {intercepts[k]:.17g}\n")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--full", action="store_true",
                    help="also emit the 8K/16K sequence-length artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact-name substrings to build")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    entries = build_entries(args.full)
    if args.only:
        pats = args.only.split(",")
        entries = [e for e in entries if any(p in e[0] for p in pats)]

    manifest_lines = [
        "# name file kind dtype L d heads br bc segments num_inputs",
    ]
    for name, fn, specs, meta in entries:
        t0 = time.time()
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest_lines.append(
            f"{name} {fname} {meta['kind']} {meta['dtype']} {meta['L']} "
            f"{meta['d']} {meta['heads']} {meta['br']} {meta['bc']} "
            f"{meta['segments']} {len(specs)}"
        )
        print(f"  {fname:40s} {len(text)/1e6:7.2f} MB  {time.time()-t0:5.1f}s")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    write_coeff_tables(args.out)
    with open(os.path.join(args.out, ".stamp"), "w") as f:
        f.write(str(time.time()) + "\n")
    print(f"wrote {len(entries)} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
