"""Pure-jnp correctness oracles for the FSA attention kernel.

Three references, in decreasing strictness of what they share with the
Pallas kernel:

* :func:`flash_pwl`   — same tiling, same Algorithm-1 FP op order, same
  PWL exp2.  The Pallas kernel must match this to ~1e-5 (it *is* the same
  math outside pallas machinery).
* :func:`flash_exact` — same tiling and op order, exact exp2.  Difference
  vs flash_pwl isolates the PWL approximation error (paper §6.2.2).
* :func:`sdpa`        — dense fp32 scaled-dot-product attention, the
  paper's external reference (stand-in for torch SDPA).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .pwl import LOG2E, coefficients, pwl_exp2

NEG_INF = -1e30  # finite -inf stand-in; keeps fp16 arithmetic NaN-free


def sdpa(q, k, v):
    """Dense fp32 softmax(Q K^T / sqrt(d)) V."""
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    d = q.shape[-1]
    s = jnp.matmul(q, k.T) / math.sqrt(d)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.matmul(p, v)


def pwl_exp2_f16mac(x, segments: int = 8):
    """PWL exp2 with the interpolation MAC on the fp16 PE datapath."""
    slopes, intercepts = coefficients(segments)
    s16 = jnp.asarray(slopes, jnp.float16)
    c16 = jnp.asarray(intercepts, jnp.float16)
    xi = jnp.ceil(x)
    xf = x - xi
    kk = jnp.clip(jnp.floor(-xf * segments).astype(jnp.int32), 0, segments - 1)
    frac = (s16[kk] * xf.astype(jnp.float16) + c16[kk]).astype(jnp.float32)
    return jnp.exp2(jnp.clip(xi, -126.0, 127.0)) * frac


def _flash(q, k, v, br: int, bc: int, exp2_fn):
    """FlashAttention-2 forward, Algorithm 1 of the paper, tile by tile.

    Matmul inputs stay in the caller dtype (fp16 on FSA); reductions and
    accumulators are fp32, matching '16-bit activation / 32-bit
    accumulation' of Table 1.
    """
    L, d = q.shape
    Lk = k.shape[0]
    if L % br or Lk % bc:
        raise ValueError(f"seq lens ({L},{Lk}) not divisible by tiles ({br},{bc})")
    scale = LOG2E / math.sqrt(d)
    tr, tc = L // br, Lk // bc
    out = []
    for i in range(tr):
        qi = q[i * br : (i + 1) * br]
        m = jnp.full((br,), NEG_INF, jnp.float32)
        l = jnp.zeros((br,), jnp.float32)
        acc = jnp.zeros((br, d), jnp.float32)
        for j in range(tc):
            kj = k[j * bc : (j + 1) * bc]
            vj = v[j * bc : (j + 1) * bc]
            s = jnp.matmul(qi, kj.T, preferred_element_type=jnp.float32)
            if q.dtype == jnp.float16:
                # S parks in fp16 result registers on the device.
                s = s.astype(jnp.float16).astype(jnp.float32)
            local_m = jnp.max(s, axis=1)
            new_m = jnp.maximum(m, local_m)
            a = m - new_m
            b = exp2_fn(scale * a)
            n = s - new_m[:, None]
            p = exp2_fn(scale * n)
            # In fp16 mode, P lives in the device's fp16 (FTZ) registers;
            # the rowsum and the PV matmul both read those stored values.
            if q.dtype == jnp.float16:
                p16 = p.astype(jnp.float16)
                p16 = jnp.where(
                    jnp.abs(p16) < jnp.float16(2.0 ** -14), jnp.float16(0), p16
                )
                p = p16.astype(jnp.float32)
            local_l = jnp.sum(p, axis=1)
            l = l * b + local_l
            pv = jnp.matmul(p.astype(q.dtype), vj, preferred_element_type=jnp.float32)
            acc = b[:, None] * acc + pv
            m = new_m
        out.append(acc / l[:, None])
    return jnp.concatenate(out, axis=0).astype(q.dtype)


def flash_exact(q, k, v, br: int = 128, bc: int = 128):
    """Tiled FlashAttention with exact exp2 (isolates tiling/op-order)."""
    return _flash(q, k, v, br, bc, jnp.exp2)


def flash_pwl(q, k, v, br: int = 128, bc: int = 128, segments: int = 8):
    """Tiled FlashAttention with FSA's PWL exp2 — the kernel's strict twin.

    fp16 inputs use the fp16 interpolation MAC, matching both the kernel
    and the silicon; f32 inputs keep the f32 PWL.
    """
    fn = (functools.partial(pwl_exp2_f16mac, segments=segments)
          if q.dtype == jnp.float16
          else functools.partial(pwl_exp2, segments=segments))
    return _flash(q, k, v, br, bc, fn)
