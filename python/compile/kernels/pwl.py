"""Piecewise-linear exp2 — the numerics contract of the FSA Split+PWL unit.

The paper (§3.3) observes that FlashAttention only ever evaluates
``exp2(x)`` for ``x <= 0``.  Decomposing ``x = xi + xf`` with integer
``xi = ceil(x)`` gives a fractional part ``xf in (-1, 0]``, hence
``2**xf in (0.5, 1]``.  FSA approximates ``2**xf`` with an S-piece uniform
piecewise-linear interpolation whose (slope, intercept) pairs are streamed
through the array and evaluated on the PE MAC units; the integer part only
shifts the result exponent.

This module is the *single source of truth* for the coefficient tables:
``aot.py`` exports them to ``artifacts/pwl_coeffs_{S}.txt`` and the Rust
``fsa::numerics::pwl`` module is golden-tested against that file.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

LOG2E = math.log2(math.e)


def coefficients(segments: int) -> tuple[np.ndarray, np.ndarray]:
    """Endpoint-interpolating PWL coefficients for 2**xf on (-1, 0].

    Segment ``k`` (k = 0..S-1) covers ``xf in [-(k+1)/S, -k/S)`` (with the
    right-closed end at xf=0 folded into k=0).  On segment ``[a, b]``::

        slope_k     = (2**b - 2**a) / (b - a)
        intercept_k = 2**a - slope_k * a      # line through both endpoints

    Returns float64 arrays (callers quantize as needed).  All intercepts
    land in (0.5, 1] — the property FSA uses to encode the segment index k
    in the intercept's exponent MSBs (checked in tests on both layers).
    """
    if segments < 1:
        raise ValueError(f"segments must be >= 1, got {segments}")
    slopes = np.empty(segments, dtype=np.float64)
    intercepts = np.empty(segments, dtype=np.float64)
    for k in range(segments):
        b = -k / segments
        a = -(k + 1) / segments
        s = (2.0**b - 2.0**a) / (b - a)
        c = 2.0**a - s * a
        slopes[k] = s
        intercepts[k] = c
    return slopes, intercepts


def split_int_frac(x):
    """Decompose x (x <= 0 expected) into (xi, xf) with xf in (-1, 0]."""
    xi = jnp.ceil(x)
    xf = x - xi
    return xi, xf


def pwl_exp2(x, segments: int = 8, dtype=jnp.float32):
    """exp2(x) for x <= 0 via the FSA Split + PWL scheme (pure jnp).

    Matches the hardware dataflow: slope*xf + intercept on the MAC, then a
    2**xi exponent adjustment.  Saturates to 0 below the f32 exponent
    range, mirroring flush-to-zero accumulators.
    """
    slopes, intercepts = coefficients(segments)
    s_tab = jnp.asarray(slopes, dtype=dtype)
    c_tab = jnp.asarray(intercepts, dtype=dtype)
    x = x.astype(dtype)
    xi, xf = split_int_frac(x)
    k = jnp.clip(jnp.floor(-xf * segments).astype(jnp.int32), 0, segments - 1)
    frac = s_tab[k] * xf + c_tab[k]
    # 2**xi applied as an exact exponent shift; clamp so that the
    # intermediate exp2 never overflows (xi <= 0 in FlashAttention, but the
    # guard keeps the helper total for stray positive inputs in tests).
    xi = jnp.clip(xi, -126.0, 127.0)
    return jnp.exp2(xi.astype(dtype)) * frac


def pwl_exp2_np(x: np.ndarray, segments: int = 8) -> np.ndarray:
    """NumPy float64 twin of :func:`pwl_exp2` (reference for error sweeps)."""
    slopes, intercepts = coefficients(segments)
    x = np.asarray(x, dtype=np.float64)
    xi = np.ceil(x)
    xf = x - xi
    k = np.clip(np.floor(-xf * segments).astype(np.int64), 0, segments - 1)
    frac = slopes[k] * xf + intercepts[k]
    return np.exp2(np.clip(xi, -1074, 1023)) * frac
