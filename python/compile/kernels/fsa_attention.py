"""L1 — the FSA FlashAttention forward pass as a Pallas kernel.

This kernel is the software twin of what the FSA silicon executes
(paper Algorithm 1 + §3): one pass over the K/V sequence per Q row-block,
rowmax/rowsum carried online, ``exp`` realized as
``exp2(log2(e)/sqrt(d) * x)`` through the Split + piecewise-linear scheme
of §3.3, fp16 matmul operands with fp32 accumulation, and the exact
FlashAttention floating-point operation order (the property the paper
preserves for numerical stability).

The kernel is always lowered with ``interpret=True``: the CPU PJRT plugin
used by the Rust runtime cannot execute Mosaic custom-calls.  On a real
TPU the same BlockSpec structure maps Br=Bc=d=128 tiles into VMEM with two
back-to-back 128x128x128 MXU matmuls per grid step (see DESIGN.md
§Hardware-Adaptation for the VMEM/MXU budget).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pwl import LOG2E, coefficients
from .ref import NEG_INF


FP16_MIN_NORMAL = 2.0 ** -14


def _pwl_exp2_tab(x, s_tab, c_tab, segments: int, f16_mac: bool = False):
    """In-kernel PWL exp2 (x <= 0): Split -> MAC interpolation -> 2**xi.

    With ``f16_mac`` the interpolation runs on the half-precision PE
    datapath (fp16 fraction, fp16 coefficients, fp16-rounded MAC result),
    matching the silicon; the 2**xi exponent shift is exact either way.
    """
    xi = jnp.ceil(x)
    xf = x - xi
    k = jnp.clip(jnp.floor(-xf * segments).astype(jnp.int32), 0, segments - 1)
    if f16_mac:
        xf16 = xf.astype(jnp.float16)
        s16 = s_tab.astype(jnp.float16)
        c16 = c_tab.astype(jnp.float16)
        frac = (jnp.take(s16, k) * xf16 + jnp.take(c16, k)).astype(jnp.float32)
    else:
        frac = jnp.take(s_tab, k) * xf + jnp.take(c_tab, k)
    xi = jnp.clip(xi, -126.0, 127.0)
    return jnp.exp2(xi) * frac


def _ftz_f16(x):
    """fp16 quantization with flush-to-zero on subnormals.

    The paper assumes accelerator flush-to-zero semantics (§6.2.1); jnp's
    astype keeps subnormals, so the flush is applied explicitly.  This is
    what makes the Table-2 error grow with sequence length: softmax
    weights scale like 1/L and start underflowing the fp16 normal range
    near L = 16K.
    """
    q = x.astype(jnp.float16)
    return jnp.where(jnp.abs(q) < jnp.float16(FP16_MIN_NORMAL), jnp.float16(0), q)


def _flash_kernel(q_ref, k_ref, v_ref, s_ref, c_ref, o_ref, *, bc: int,
                  segments: int, scale: float):
    br, d = q_ref.shape
    lk = k_ref.shape[0]
    tc = lk // bc
    dtype = q_ref.dtype

    # PWL coefficient tables stream in as kernel operands, mirroring the
    # hardware, which streams (slope_k, intercept_k) from the array edges
    # rather than storing them in the PEs (§3.3).  fp16 inputs run the
    # interpolation on the fp16 PE datapath, like the silicon.
    e2 = functools.partial(
        _pwl_exp2_tab, s_tab=s_ref[...], c_tab=c_ref[...], segments=segments,
        f16_mac=dtype == jnp.float16,
    )

    q = q_ref[...]

    def body(j, carry):
        m, l, acc = carry
        kj = pl.load(k_ref, (pl.dslice(j * bc, bc), slice(None)))
        vj = pl.load(v_ref, (pl.dslice(j * bc, bc), slice(None)))
        # S = Q K^T (first matmul, upward path on FSA), fp32 psums.
        s = jax.lax.dot_general(
            q, kj, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        if dtype == jnp.float16:
            # S parks in the fp16 PE result registers on the device.
            s = s.astype(jnp.float16).astype(jnp.float32)
        local_m = jnp.max(s, axis=1)          # CMP row, on the fly
        new_m = jnp.maximum(m, local_m)
        b = e2(scale * (m - new_m))           # accumulator scale factor
        n = s - new_m[:, None]                # in-place subtract (left=1, top=-new_m)
        p = e2(scale * n)                     # Split + PWL on resident tile
        # In fp16 mode P lives in the fp16 (FTZ) PE result registers; the
        # rowsum sums those *stored* values (downward, left=1, top=0), and
        # the second matmul reads the same registers.  f32 mode stays pure
        # for the strict-twin tests.
        if dtype == jnp.float16:
            p16 = _ftz_f16(p).astype(dtype)
            local_l = jnp.sum(p16.astype(jnp.float32), axis=1)
        else:
            p16 = p.astype(dtype)
            local_l = jnp.sum(p, axis=1)
        new_l = l * b + local_l
        pv = jax.lax.dot_general(
            p16, vj, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        new_acc = b[:, None] * acc + pv
        return new_m, new_l, new_acc

    m0 = jnp.full((br,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((br,), jnp.float32)
    acc0 = jnp.zeros((br, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, tc, body, (m0, l0, acc0))
    # Attn LSE Norm: reciprocal + scale (paper §4.2 outer-loop phases).
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def fsa_attention(q, k, v, br: int = 128, bc: int = 128, segments: int = 8):
    """Single-head FlashAttention on FSA numerics.

    Args:
      q: ``(L, d)`` queries.  k, v: ``(Lk, d)`` keys/values (same dtype).
      br, bc: row/column tile sizes; on FSA hardware ``br = N_COLS`` and
        ``bc = N_ROWS = d`` (§3.5), but the kernel accepts any divisor
        tiling so tests can sweep shapes.
      segments: PWL segment count (paper default 8).

    Returns ``(L, d)`` attention output in the input dtype.
    """
    L, d = q.shape
    lk, dk = k.shape
    if dk != d or v.shape != (lk, d):
        raise ValueError(f"shape mismatch: q={q.shape} k={k.shape} v={v.shape}")
    if L % br or lk % bc:
        raise ValueError(f"L={L},Lk={lk} not divisible by br={br},bc={bc}")
    scale = LOG2E / math.sqrt(d)
    grid = (L // br,)
    slopes, intercepts = coefficients(segments)
    s_tab = jnp.asarray(slopes, jnp.float32)
    c_tab = jnp.asarray(intercepts, jnp.float32)
    return pl.pallas_call(
        functools.partial(_flash_kernel, bc=bc, segments=segments, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((lk, d), lambda i: (0, 0)),
            pl.BlockSpec((lk, d), lambda i: (0, 0)),
            pl.BlockSpec((segments,), lambda i: (0,)),
            pl.BlockSpec((segments,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((L, d), q.dtype),
        interpret=True,
    )(q, k, v, s_tab, c_tab)


def fsa_attention_mha(q, k, v, br: int = 128, bc: int = 128, segments: int = 8):
    """Multi-head wrapper: ``(H, L, d)`` inputs, vmapped over heads."""
    f = functools.partial(fsa_attention, br=br, bc=bc, segments=segments)
    return jax.vmap(f)(q, k, v)
