"""PWL exp2 unit tests — the numerics contract both layers depend on."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.pwl import LOG2E, coefficients, pwl_exp2, pwl_exp2_np

SEGMENTS = [1, 2, 4, 8, 16, 32, 64]


@pytest.mark.parametrize("s", SEGMENTS)
def test_intercepts_in_half_open_unit_range(s):
    # Paper §3.3: all intercepts lie in (0.5, 1], so their exponent is 0 or
    # -1 and the MSBs can encode the segment index k.
    _, intercepts = coefficients(s)
    assert np.all(intercepts > 0.5)
    assert np.all(intercepts <= 1.0)


@pytest.mark.parametrize("s", SEGMENTS)
def test_endpoint_interpolation_exact(s):
    # The PWL is exact at every segment breakpoint.
    slopes, intercepts = coefficients(s)
    for k in range(s):
        for x in (-k / s, -(k + 1) / s):
            approx = slopes[k] * x + intercepts[k]
            assert math.isclose(approx, 2.0**x, rel_tol=1e-12)


@pytest.mark.parametrize("s", SEGMENTS)
def test_pwl_continuous_and_monotone(s):
    # Adjacent segments meet at breakpoints; slopes are positive and
    # decreasing in k (2^x is increasing and convex on (-1, 0]).
    slopes, intercepts = coefficients(s)
    assert np.all(slopes > 0)
    assert np.all(np.diff(slopes) < 0) or s == 1
    for k in range(s - 1):
        x = -(k + 1) / s
        left = slopes[k] * x + intercepts[k]
        right = slopes[k + 1] * x + intercepts[k + 1]
        assert math.isclose(left, right, rel_tol=1e-12)


def test_error_decreases_with_segments():
    x = np.linspace(-20, 0, 20001)
    exact = np.exp2(x)
    errs = []
    for s in SEGMENTS:
        errs.append(np.mean(np.abs(pwl_exp2_np(x, s) - exact)))
    assert all(a > b for a, b in zip(errs, errs[1:]))


def test_eight_segment_max_rel_error_bound():
    # Interp theory: max rel err <= (ln2)^2 / (8 * 64) / 2^xf < 2e-3.
    x = np.linspace(-1, 0, 100001)
    rel = np.abs(pwl_exp2_np(x, 8) - np.exp2(x)) / np.exp2(x)
    assert rel.max() < 2e-3


@settings(deadline=None, max_examples=200)
@given(st.floats(min_value=-80.0, max_value=0.0), st.sampled_from(SEGMENTS))
def test_jnp_matches_np(x, s):
    a = float(pwl_exp2(np.float32(x), segments=s))
    b = float(pwl_exp2_np(np.array([x]), s)[0])
    assert a == pytest.approx(b, rel=1e-5, abs=1e-38)


@settings(deadline=None, max_examples=100)
@given(st.integers(min_value=-30, max_value=0))
def test_exact_at_integers(xi):
    # xf = 0 lands in segment 0 whose intercept is exactly 1.
    assert float(pwl_exp2(np.float32(xi), segments=8)) == pytest.approx(
        2.0**xi, rel=1e-6
    )
