"""L2 model-level tests: shapes, composition, and AOT lowering round-trip."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def test_mha_proj_shapes_and_reference():
    rng = np.random.default_rng(0)
    H, L, d = 2, 64, 16
    D = H * d
    x = jnp.asarray(rng.standard_normal((L, D)) * 0.3, jnp.float32)
    ws = [jnp.asarray(rng.standard_normal((D, D)) / np.sqrt(D), jnp.float32)
          for _ in range(4)]
    (out,) = model.mha_proj(x, *ws, heads=H, br=16, bc=16)
    assert out.shape == (L, D)
    # Reference: same projections + dense SDPA per head.
    q = (x @ ws[0]).reshape(L, H, d).transpose(1, 0, 2)
    k = (x @ ws[1]).reshape(L, H, d).transpose(1, 0, 2)
    v = (x @ ws[2]).reshape(L, H, d).transpose(1, 0, 2)
    heads = jnp.stack([ref.sdpa(q[h], k[h], v[h]) for h in range(H)])
    want = heads.transpose(1, 0, 2).reshape(L, D) @ ws[3]
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=5e-3, atol=5e-3)


def test_mha_proj_rejects_bad_heads():
    x = jnp.zeros((32, 48), jnp.float32)
    w = jnp.zeros((48, 48), jnp.float32)
    with pytest.raises(ValueError, match="divisible"):
        model.mha_proj(x, w, w, w, w, heads=5, br=16, bc=16)


def test_entry_points_return_tuples():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    for fn in (model.fsa_attn, model.flash_exact):
        out = fn(q, q, q, br=16, bc=16)
        assert isinstance(out, tuple) and len(out) == 1
    out = model.sdpa(q, q, q)
    assert isinstance(out, tuple) and len(out) == 1


def test_lowering_produces_parseable_hlo_text():
    spec = jax.ShapeDtypeStruct((128, 128), jnp.float16)
    lowered = jax.jit(
        lambda q, k, v: model.fsa_attn(q, k, v, br=128, bc=128)
    ).lower(spec, spec, spec)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "f16[128,128]" in text
    # return_tuple=True: root computation returns a tuple.
    assert "(f16[128,128]" in text


def test_build_entries_cover_paper_sizes():
    names = [e[0] for e in aot.build_entries(full=True)]
    for L in (2048, 4096, 8192, 16384):
        assert f"fsa_attn_L{L}_d128" in names
        assert f"flash_exact_L{L}_d128" in names
    assert not any("sdpa_L16384" in n for n in names)  # dense ref capped


ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(not os.path.exists(os.path.join(ARTIFACTS, "manifest.txt")),
                    reason="artifacts not built")
def test_manifest_consistent_with_files():
    with open(os.path.join(ARTIFACTS, "manifest.txt")) as f:
        lines = [l.split() for l in f if l.strip() and not l.startswith("#")]
    assert len(lines) >= 10
    for parts in lines:
        assert len(parts) == 11
        assert os.path.exists(os.path.join(ARTIFACTS, parts[1])), parts[1]
        L, d = int(parts[4]), int(parts[5])
        assert L >= 128 and d == 128
