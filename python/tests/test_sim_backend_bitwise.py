"""Bitwise contract of the `backend=sim` serving path (DESIGN.md section 8).

Operation-level float32/float16 mirror of BOTH sides of the Rust claim:

  * `ref_partial` mirrors `numerics/reference.rs::flash_forward_partial`
    with PWL-f16 exp2 and fp16 operand quantization (the reference
    backend's kernel, ragged tiles + masks at global key coordinates);
  * `sim_partial` mirrors the arithmetic the cycle simulator performs
    when executing `kernel::flash_chunk_program` on `sim::Machine` with
    the section-8 mask wave: K/V/Q zero-padded to whole N x N tiles, a
    per-column CMP lane boundary (`MaskBound` + the AttnScore mask
    flag) that excludes masked lanes from the rowmax and parks them as
    zero via the PE masked latch, rowsum/PV accumulating the zeroed
    lanes, and the accumulator's `b = exp2(scale * (old_m - new_m))`
    rescale (b = 0 on `first`).

The test asserts the two produce BITWISE-identical outputs (u32 bit
patterns) over shapes x masks x chunk offsets, including the br = 1
decode degeneration and the unnormalized partial (acc, m, l) state the
sequence-parallel gather merges.  This is the machine-checkable core of
the PR's acceptance criterion (`backend=sim` e2e outputs bitwise-equal
to `backend=reference`); the Rust e2e tests pin the same claim through
the coordinator.

Run directly (no pytest needed):  python3 python/tests/test_sim_backend_bitwise.py
"""

import math

import numpy as np

F32 = np.float32
LOG2E = 1.4426950408889634
NEG_INF = F32(-1e30)


# ----------------------------------------------------------------------
# fp16 helpers (mirror rust/src/numerics/f16.rs)
# ----------------------------------------------------------------------

def f16_round(x):
    """F16::from_f32().to_f32(): IEEE RNE, NO subnormal flush."""
    return np.asarray(x, F32).astype(np.float16).astype(F32)


def q16(x):
    """quantize_ftz_f32: RNE + flush-to-zero on f16 subnormals (sign kept)."""
    h = np.asarray(x, F32).astype(np.float16)
    sub = (h != 0) & (np.abs(h.astype(F32)) < F32(2.0 ** -14))
    h = np.where(sub, np.copysign(np.float16(0.0), h), h)
    return h.astype(F32)


# ----------------------------------------------------------------------
# PWL exp2 (mirror rust/src/numerics/pwl.rs)
# ----------------------------------------------------------------------

class Pwl:
    def __init__(self, segments=8):
        self.s = segments
        self.slopes, self.intercepts = [], []
        for k in range(segments):
            b = -k / segments
            a = -(k + 1) / segments
            slope = (2.0 ** b - 2.0 ** a) / (b - a)
            self.slopes.append(slope)
            self.intercepts.append(2.0 ** a - slope * a)

    def segment(self, xf):
        k = math.floor(-float(xf) * self.s)
        return min(max(k, 0), self.s - 1)

    def eval_f16_mac(self, x):
        """Reference evaluator (f16_round, no FTZ on xf/frac)."""
        x = F32(x)
        xi = F32(np.ceil(x))
        xf = f16_round(x - xi)
        k = self.segment(xf)
        slope = f16_round(F32(self.slopes[k]))
        intercept = f16_round(F32(self.intercepts[k]))
        frac = f16_round(F32(slope * xf) + intercept)
        return F32(frac * F32(np.exp2(F32(np.clip(xi, -126.0, 127.0)))))

    def sim_pe(self, x):
        """The PE Split-unit path (array.rs, q_res = quantize_ftz):
        res = q16(frac * 2^xi) with xf/frac through q16."""
        x = F32(x)
        xi = F32(np.ceil(x))
        xf = q16(x - xi)
        k = self.segment(xf)
        slope = q16(F32(self.slopes[k]))        # injected operand, quantized
        intercept = q16(F32(self.intercepts[k]))
        frac = q16(F32(slope * xf) + intercept)
        return q16(F32(frac * F32(np.exp2(F32(np.clip(xi, -126.0, 127.0))))))


PWL = Pwl(8)


def valid_keys(mask, i, lk):
    kind, arg = mask
    if kind == "none":
        return lk
    if kind == "causal":
        return min(i + 1, lk)
    return min(arg, lk)  # padding


# ----------------------------------------------------------------------
# Reference mirror: flash_forward_partial (PwlF16 + F16F32, ragged tiles)
# ----------------------------------------------------------------------

def ref_partial(q, k, v, br, bc, mask, key_offset, total_keys):
    l_rows, d = q.shape
    lk = k.shape[0]
    scale = F32(LOG2E / math.sqrt(d))
    qq, kq, vq = q16(q), q16(k), q16(v)
    m = np.full(l_rows, NEG_INF, F32)
    lsum = np.zeros(l_rows, F32)
    acc = np.zeros((l_rows, d), F32)

    q0 = 0
    while q0 < l_rows:
        bre = min(br, l_rows - q0)
        k0 = 0
        while k0 < lk:
            bce = min(bc, lk - k0)
            # tile-skipping: coverage at global key coords
            any_live = any(
                valid_keys(mask, q0 + r, total_keys) - (key_offset + k0) > 0
                for r in range(bre)
            )
            if not any_live:
                k0 += bce
                continue
            p16 = np.zeros((bre, bce), F32)
            bvec = np.zeros(bre, F32)
            touched = np.zeros(bre, bool)
            for r in range(bre):
                vc = min(max(valid_keys(mask, q0 + r, total_keys) - (key_offset + k0), 0), bce)
                if vc == 0:
                    continue
                touched[r] = True
                s = np.zeros(vc, F32)
                for c in range(vc):
                    ps = F32(0.0)
                    for kk in reversed(range(d)):
                        ps = F32(ps + F32(qq[q0 + r, kk] * kq[k0 + c, kk]))
                    s[c] = ps
                s = q16(s)
                local_m = s.max()
                new_m = max(m[q0 + r], local_m)
                b = PWL.eval_f16_mac(F32(scale * F32(m[q0 + r] - new_m)))
                local_l = F32(0.0)
                for c in range(vc):
                    nv = q16(F32(s[c] - new_m))
                    pv = PWL.eval_f16_mac(q16(F32(scale * nv)))
                    p16[r, c] = q16(pv)
                    local_l = F32(local_l + p16[r, c])
                for c in range(vc, bce):
                    p16[r, c] = F32(0.0)
                    local_l = F32(local_l + p16[r, c])
                lsum[q0 + r] = F32(F32(lsum[q0 + r] * b) + local_l)
                m[q0 + r] = new_m
                bvec[r] = b
            for r in range(bre):
                if not touched[r]:
                    continue
                acc[q0 + r, :] = F32(acc[q0 + r, :] * bvec[r])
                for h in range(d):
                    ps = F32(0.0)
                    for c in range(bce):
                        ps = F32(ps + F32(p16[r, c] * vq[k0 + c, h]))
                    acc[q0 + r, h] = F32(acc[q0 + r, h] + ps)
            k0 += bce
        q0 += bre
    return acc, m, lsum


def ref_finalize(acc, lsum):
    out = np.zeros_like(acc)
    for r in range(acc.shape[0]):
        if lsum[r] == 0.0:
            continue
        inv = F32(F32(1.0) / lsum[r])
        out[r, :] = F32(acc[r, :] * inv)
    return out


# ----------------------------------------------------------------------
# Sim mirror: the arithmetic of flash_chunk_program on sim::Machine
# ----------------------------------------------------------------------

def pad_to(mat, rows, cols):
    out = np.zeros((rows, cols), F32)
    out[: mat.shape[0], : mat.shape[1]] = mat
    return out


def sim_partial(q, k, v, n, mask, key_offset, total_keys, scale_dim):
    """One head on the padded array: q (valid_q, d) etc; returns the
    (padded) acc/m/l arrays the caller slices."""
    valid_q, d = q.shape
    valid_k = k.shape[0]
    lq = -(-valid_q // n) * n
    lkp = -(-valid_k // n) * n
    qp = q16(pad_to(q, lq, n))   # DMA-load quantization
    kp = q16(pad_to(k, lkp, n))
    vp = q16(pad_to(v, lkp, n))
    scale = F32(LOG2E / math.sqrt(scale_dim))

    acc = np.zeros((lq, n), F32)   # O rows (de-transposed view)
    mcol = np.full(lq, NEG_INF, F32)
    lvec = np.zeros(lq, F32)

    for blk in range(lq // n):
        gq0 = blk * n
        stat = qp[gq0 : gq0 + n, :]          # stationary Q tile
        run_m = np.full(n, NEG_INF, F32)     # CMP new_m after reset
        first = True
        rows_real = min(n, valid_q - gq0)
        for j in range(lkp // n):
            lk0 = j * n
            w = min(n, valid_k - lk0)
            if w <= 0:
                continue
            bound = np.array(
                [
                    min(max(valid_keys(mask, gq0 + mm, total_keys) - (key_offset + lk0), 0), w)
                    for mm in range(n)
                ]
            )
            if not any(bound[mm] > 0 for mm in range(rows_real)):
                continue  # tile never issued
            kt = kp[lk0 : lk0 + n, :]
            vt = vp[lk0 : lk0 + n, :]
            # first matmul: psum over kdim descending (upward path)
            ps = np.zeros((n, n), F32)  # ps[m, nn]
            for kk in reversed(range(n)):
                ps = F32(ps + F32(stat[:, kk][:, None] * kt[:, kk][None, :]))
            s_q = q16(ps)  # CMP fp16 register quantization
            lane_ok = np.arange(n)[None, :] < bound[:, None]  # [m, nn]
            # CMP rowmax over valid lanes only
            masked_s = np.where(lane_ok, s_q, NEG_INF)
            tile_max = masked_s.max(axis=1)
            new_m = np.maximum(run_m, tile_max)
            # park: masked lanes park 0 and latch masked
            res = np.where(lane_ok, s_q, F32(0.0))
            # elementwise chain skips masked PEs
            res = np.where(lane_ok, q16(F32(res + (-new_m)[:, None])), res)
            res = np.where(lane_ok, q16(F32(res * scale)), res)
            pwl_res = np.zeros_like(res)
            for mm in range(n):
                for nn in range(int(bound[mm])):
                    pwl_res[mm, nn] = PWL.sim_pe(res[mm, nn])
            res = np.where(lane_ok, pwl_res, res)
            # rowsum wave: ascending over nn, masked lanes contribute 0.0
            local_l = np.zeros(n, F32)
            for nn in range(n):
                local_l = F32(local_l + res[:, nn])
            # accumulator: a = old_m - new_m, b = eval(scale * a); first -> 0
            if first:
                b = np.zeros(n, F32)
            else:
                a = F32(run_m - new_m)
                b = np.array([PWL.eval_f16_mac(F32(scale * a[mm])) for mm in range(n)], F32)
            lvec[gq0 : gq0 + n] = F32(F32(lvec[gq0 : gq0 + n] * b) + local_l)
            # PV: psums ascending over nn; masked lanes ride P = 0
            ps_o = np.zeros((n, n), F32)  # [m, h]
            for nn in range(n):
                ps_o = F32(ps_o + F32(res[:, nn][:, None] * vt[nn, :][None, :]))
            acc[gq0 : gq0 + n, :] = F32(
                F32(acc[gq0 : gq0 + n, :] * b[:, None]) + ps_o
            )
            run_m = new_m
            first = False
        mcol[gq0 : gq0 + n] = run_m
    return acc, mcol, lvec


def sim_finalize(acc, lvec):
    """Epilogue: Reciprocal (1/0 flushed to 0, the defined-zero rule for
    fully-masked rows) + AttnLseNorm."""
    inv = np.where(lvec == 0.0, F32(0.0), F32(F32(1.0) / lvec))
    return F32(acc * inv[:, None])


# ----------------------------------------------------------------------
# The assertions
# ----------------------------------------------------------------------

def bits(x):
    return np.ascontiguousarray(np.asarray(x, F32)).view(np.uint32)


def assert_bitwise(a, b, what):
    if not np.array_equal(bits(a), bits(b)):
        diff = np.argwhere(bits(a) != bits(b))
        raise AssertionError(
            f"{what}: {len(diff)} of {a.size} elements differ; first at "
            f"{diff[0]}: {np.asarray(a).flat[np.ravel_multi_index(tuple(diff[0]), np.asarray(a).shape)]} "
            f"vs {np.asarray(b).flat[np.ravel_multi_index(tuple(diff[0]), np.asarray(b).shape)]}"
        )


def check_case(rng, l_rows, d, n, mask, key_offset=0, total=None, chunk=None):
    total = total if total is not None else l_rows
    q = rng.standard_normal((l_rows, d)).astype(F32)
    lk = chunk if chunk is not None else total - key_offset
    k = rng.standard_normal((lk, d)).astype(F32)
    v = rng.standard_normal((lk, d)).astype(F32)

    r_acc, r_m, r_l = ref_partial(q, k, v, n, n, mask, key_offset, total)
    s_acc, s_m, s_l = sim_partial(q, k, v, n, mask, key_offset, total, d)
    what = f"L={l_rows} d={d} n={n} mask={mask} off={key_offset} lk={lk}"
    assert_bitwise(s_acc[:l_rows, :d], r_acc, f"{what}: partial acc")
    assert_bitwise(s_m[:l_rows], r_m, f"{what}: partial m")
    assert_bitwise(s_l[:l_rows], r_l, f"{what}: partial l")
    out_ref = ref_finalize(r_acc, r_l)
    out_sim = sim_finalize(s_acc, s_l)[:l_rows, :d]
    assert_bitwise(out_sim, out_ref, f"{what}: normalized output")
    print(f"  ok  {what}")


def test_exp2_at_zero_is_one():
    # The b = 1.0 identity for columns masked in one tile but live in
    # another: eval_f16_mac(0) must be exactly 1.0.
    assert float(PWL.eval_f16_mac(F32(0.0))) == 1.0
    assert float(PWL.sim_pe(F32(0.0))) == 1.0


def test_sim_bitwise_matches_reference():
    rng = np.random.default_rng(0xF5A)
    # Whole-head shapes: exact tiles, ragged queries/keys, padded d < n.
    check_case(rng, 64, 32, 32, ("none", 0))
    check_case(rng, 64, 32, 32, ("causal", 0))
    check_case(rng, 64, 32, 32, ("padding", 40))
    check_case(rng, 40, 16, 32, ("none", 0))       # ragged rows+cols, d < n
    check_case(rng, 40, 16, 32, ("causal", 0))
    check_case(rng, 100, 32, 32, ("padding", 70))  # boundary mid-tile
    check_case(rng, 33, 8, 16, ("causal", 0))      # heavy padding
    # Sequence-parallel chunks at global coordinates (incl. a chunk the
    # causal mask partially kills: rows 0..31 see none of keys 32..63).
    check_case(rng, 64, 32, 32, ("none", 0), key_offset=32, total=64, chunk=32)
    check_case(rng, 64, 32, 32, ("causal", 0), key_offset=32, total=64, chunk=32)
    check_case(rng, 64, 32, 32, ("padding", 40), key_offset=32, total=64, chunk=32)
    check_case(rng, 64, 16, 32, ("causal", 0), key_offset=16, total=64, chunk=48)
    # br = 1 decode rows (ragged prefix; the decode program shape).
    check_case(rng, 1, 32, 32, ("none", 0), total=37, chunk=37)
    check_case(rng, 1, 16, 32, ("none", 0), total=64, chunk=64)
    # split-KV decode range
    check_case(rng, 1, 32, 32, ("none", 0), key_offset=16, total=48, chunk=32)
    # Larger shapes (satellite of the vectorization PR: the raised
    # sim_max_seq default means longer heads ride the sim path, so the
    # bitwise contract gets pinned on multi-block multi-tile runs too).
    check_case(rng, 160, 32, 32, ("causal", 0))
    check_case(rng, 224, 32, 32, ("padding", 150), key_offset=64, total=224, chunk=96)


def rust_lane_bound(mask, n, valid_q, valid_k, key_offset, total, block, col_tile):
    """Mirror of kernel::ChunkParams::tile_bound (the LaneBound the Rust
    kernel encodes into MaskBound): returns (live, bound_fn)."""
    gq0, lk0 = block * n, col_tile * n
    w = min(n, max(valid_k - lk0, 0))
    gk0 = key_offset + lk0
    kind = mask[0]
    if kind == "causal":
        base, diag, cap = gq0 + 1 - gk0, 1, w
    elif kind == "none":
        base, diag, cap = w, 0, w
    else:
        base, diag, cap = min(max(mask[1] - gk0, 0), w), 0, w

    def bound(m):
        return min(max(base + diag * m, 0), cap)

    rows_real = min(n, max(valid_q - gq0, 0))
    live = w > 0 and any(bound(m) > 0 for m in range(rows_real))
    return live, bound


def test_rust_lane_bound_matches_reference_formula():
    """The LaneBound encoding must reproduce, for every REAL query row,
    the reference kernel's valid-lane prefix clamp(valid_keys(q) -
    key_offset - lk0, 0, w) — and classify liveness identically."""
    for n in (16, 32):
        for valid_q in (1, 33, 40, 64):
            for key_offset, valid_k, total in ((0, 64, 64), (32, 32, 64), (16, 48, 64), (0, 37, 37)):
                for mask in (("none", 0), ("causal", 0), ("padding", 40), ("padding", 20)):
                    blocks = -(-valid_q // n)
                    tiles = -(-valid_k // n)
                    for b in range(blocks):
                        for j in range(tiles):
                            live, bound = rust_lane_bound(
                                mask, n, valid_q, valid_k, key_offset, total, b, j
                            )
                            w = min(n, valid_k - j * n)
                            rows_real = min(n, valid_q - b * n)
                            ref = [
                                min(
                                    max(
                                        valid_keys(mask, b * n + m, total)
                                        - (key_offset + j * n),
                                        0,
                                    ),
                                    w,
                                )
                                for m in range(rows_real)
                            ]
                            got = [bound(m) for m in range(rows_real)]
                            assert got == ref, (
                                f"n={n} vq={valid_q} off={key_offset} vk={valid_k} "
                                f"mask={mask} tile=({b},{j}): {got} != {ref}"
                            )
                            assert live == any(x > 0 for x in ref)
    print("rust LaneBound formula matches the reference prefix everywhere")


if __name__ == "__main__":
    test_exp2_at_zero_is_one()
    print("exp2(0) == 1.0 ok")
    test_rust_lane_bound_matches_reference_formula()
    test_sim_bitwise_matches_reference()
    print("ALL BITWISE CHECKS PASSED")
