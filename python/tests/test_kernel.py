"""Pallas kernel vs oracles — the CORE correctness signal of layer 1.

Strictness ladder (see kernels/ref.py):
  kernel == flash_pwl   (same math; tight tolerance)
  kernel ~= flash_exact (differs only by PWL exp2; medium tolerance)
  kernel ~= sdpa        (plus tiling/op-order effects; loose tolerance)
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.fsa_attention import fsa_attention, fsa_attention_mha


def rand_qkv(rng, L, d, dtype, spiky=False):
    """Paper §6.2.2 input distribution when spiky: N(0,1)+N(0,100)·Bern(1e-3)."""
    def one():
        x = rng.standard_normal((L, d))
        if spiky:
            x = x + rng.standard_normal((L, d)) * 10.0 * (
                rng.random((L, d)) < 1e-3
            )
        return jnp.asarray(x, dtype)
    return one(), one(), one()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float16])
@pytest.mark.parametrize("L,d,br,bc", [
    (64, 32, 16, 16),
    (128, 64, 32, 64),
    (128, 128, 128, 128),   # the paper's native tile shape
    (256, 64, 64, 32),
])
def test_kernel_matches_flash_pwl(dtype, L, d, br, bc):
    rng = np.random.default_rng(hash((L, d, br, bc)) % 2**32)
    q, k, v = rand_qkv(rng, L, d, dtype)
    got = fsa_attention(q, k, v, br=br, bc=bc)
    want = ref.flash_pwl(q, k, v, br=br, bc=bc)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=2e-3 if dtype == jnp.float16 else 1e-5,
        atol=2e-3 if dtype == jnp.float16 else 1e-6,
    )


@pytest.mark.parametrize("L,d", [(128, 64), (256, 128)])
def test_kernel_close_to_exact_sdpa(L, d):
    rng = np.random.default_rng(7)
    q, k, v = rand_qkv(rng, L, d, jnp.float32, spiky=True)
    got = np.asarray(fsa_attention(q, k, v, br=64, bc=64), np.float32)
    want = np.asarray(ref.sdpa(q, k, v), np.float32)
    # PWL error budget (paper Table 2: MAE ~1e-2 at fp16; f32 tighter).
    assert np.mean(np.abs(got - want)) < 5e-3
    assert np.max(np.abs(got - want)) < 5e-2


def test_pwl_error_isolated_from_tiling():
    # flash_exact == sdpa (tight) proves op-order/tiling is faithful;
    # kernel - flash_exact is then the PWL contribution alone.
    rng = np.random.default_rng(3)
    q, k, v = rand_qkv(rng, 128, 64, jnp.float32)
    exact = np.asarray(ref.flash_exact(q, k, v, br=32, bc=32), np.float32)
    dense = np.asarray(ref.sdpa(q, k, v), np.float32)
    np.testing.assert_allclose(exact, dense, rtol=1e-4, atol=1e-5)


def test_scale_invariance_of_output_range():
    rng = np.random.default_rng(11)
    q, k, v = rand_qkv(rng, 64, 32, jnp.float32)
    shifted = np.asarray(fsa_attention(q * 30.0, k, v, br=16, bc=16))
    assert np.all(np.isfinite(shifted))


def test_single_tile_equals_multi_tile():
    # Online-softmax across tiles must agree with a single big tile up to
    # the PWL approximation (tiling changes new_m, hence which PWL segment
    # each score lands in — a ~1e-3-level effect, same order as Table 2).
    rng = np.random.default_rng(13)
    q, k, v = rand_qkv(rng, 128, 32, jnp.float32)
    one = np.asarray(fsa_attention(q, k, v, br=128, bc=128))
    many = np.asarray(fsa_attention(q, k, v, br=16, bc=16))
    np.testing.assert_allclose(one, many, atol=2e-3)
    # With exact exp2 the tiling dependence vanishes entirely.
    one_e = np.asarray(ref.flash_exact(q, k, v, br=128, bc=128))
    many_e = np.asarray(ref.flash_exact(q, k, v, br=16, bc=16))
    np.testing.assert_allclose(one_e, many_e, rtol=1e-5, atol=1e-6)


def test_mha_matches_per_head():
    rng = np.random.default_rng(17)
    H, L, d = 3, 64, 32
    q = jnp.asarray(rng.standard_normal((H, L, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((H, L, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((H, L, d)), jnp.float32)
    got = np.asarray(fsa_attention_mha(q, k, v, br=16, bc=16))
    for h in range(H):
        want = np.asarray(fsa_attention(q[h], k[h], v[h], br=16, bc=16))
        np.testing.assert_allclose(got[h], want, rtol=1e-6, atol=1e-7)


def test_shape_validation():
    q = jnp.zeros((64, 32), jnp.float32)
    with pytest.raises(ValueError, match="divisible"):
        fsa_attention(q, q, q, br=48, bc=16)
    with pytest.raises(ValueError, match="mismatch"):
        fsa_attention(q, jnp.zeros((64, 16), jnp.float32), q)


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, dtypes, tile sizes.
# ---------------------------------------------------------------------------

tile_cases = st.sampled_from([8, 16, 32, 64])


@settings(deadline=None, max_examples=25)
@given(
    lq_tiles=st.integers(1, 4),
    lk_tiles=st.integers(1, 4),
    br=tile_cases,
    bc=tile_cases,
    d=st.sampled_from([8, 16, 32, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.float16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_vs_twin_hypothesis(lq_tiles, lk_tiles, br, bc, d, dtype, seed):
    L, Lk = lq_tiles * br, lk_tiles * bc
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((L, d)), dtype)
    k = jnp.asarray(rng.standard_normal((Lk, d)), dtype)
    v = jnp.asarray(rng.standard_normal((Lk, d)), dtype)
    got = np.asarray(fsa_attention(q, k, v, br=br, bc=bc), np.float32)
    want = np.asarray(ref.flash_pwl(q, k, v, br=br, bc=bc), np.float32)
    tol = 2e-3 if dtype == jnp.float16 else 2e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)
    assert got.shape == (L, d)
    assert np.all(np.isfinite(got))


@settings(deadline=None, max_examples=15)
@given(
    scale=st.floats(min_value=0.01, max_value=30.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_numerical_stability_under_scale(scale, seed):
    # FlashAttention's raison d'être: no overflow for large logits.
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((32, 16)) * scale, jnp.float32)
    k = jnp.asarray(rng.standard_normal((32, 16)) * scale, jnp.float32)
    v = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    out = np.asarray(fsa_attention(q, k, v, br=16, bc=16))
    assert np.all(np.isfinite(out))
    # Output is a convex combination of V rows (up to PWL wiggle).
    assert out.max() <= float(np.asarray(v).max()) + 0.2
    assert out.min() >= float(np.asarray(v).min()) - 0.2
