#!/bin/sh
# Tier-1 verify entrypoint (ROADMAP.md): release build, tests, rustdoc.
#
# Runs the same recipe the driver and CI (.github/workflows/ci.yml)
# use:
#   cargo build --release && cargo test -q && cargo doc --no-deps
# plus clippy and `cargo fmt --check` when those tools are installed.
#
# The rustdoc step is held to zero warnings (satellite requirement:
# the public API docs must stay clean).
set -eu
cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify: cargo not found on PATH." >&2
    echo "This image ships no Rust toolchain; run verify on a host with" >&2
    echo "rustc >= 1.75 (no network needed: all deps are vendored in-tree" >&2
    echo "under rust/vendor/, see DESIGN.md section 'substitutions')." >&2
    exit 2
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --examples (warnings are errors) =="
RUSTFLAGS="${RUSTFLAGS:-} -D warnings" cargo build --examples

echo "== cargo test -q =="
cargo test -q

# The vectorized-vs-scalar differential pin, run by name so its failure
# is visible even when the quiet full suite is skimmed.
echo "== cargo test --test sim_differential =="
cargo test -q --test sim_differential

echo "== cargo doc --no-deps (warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# Lint when clippy is installed (optional in minimal toolchains).
if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy (warnings are errors) =="
    cargo clippy -- -D warnings
else
    echo "== cargo clippy not installed; skipping lint =="
fi

# Format check when rustfmt is installed (mirrors the CI fmt gate).
if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    cargo fmt --check
else
    echo "== rustfmt not installed; skipping format check =="
fi

# Optional stage: every bench target at smoke iterations (exit 0 check),
# then regenerate the perf records and hold them to valid JSON with a
# reader (python3) the hand-rolled writer shares no code with.
if [ "${VERIFY_BENCH:-0}" = "1" ]; then
    echo "== make bench-smoke (VERIFY_BENCH=1) =="
    make bench-smoke
    echo "== make bench-json (smoke) =="
    FSA_BENCH_SMOKE=1 make bench-json
    if command -v python3 >/dev/null 2>&1; then
        echo "== python3 validates BENCH_*.json =="
        for f in BENCH_*.json; do
            python3 -c "import json,sys; json.load(open(sys.argv[1])); print(sys.argv[1] + ': valid JSON')" "$f"
        done
        # The serving record must carry the continuous-scheduler schema
        # (same assertions as the CI bench-smoke gate): the six
        # scenarios, per-scenario batch-occupancy / queue-depth / wave-mix
        # telemetry, the counter reconciliation invariant, and the
        # prefix-cache scenario's hit-rate / saved-cycles record
        # (DESIGN.md §11).
        echo "== python3 validates the BENCH_serving.json schema =="
        python3 - <<'EOF'
import json
serving = json.load(open("BENCH_serving.json"))
names = [s["name"] for s in serving["scenarios"]]
assert names == ["stateless_mix", "decode", "sim_attrib", "seqpar",
                 "continuous", "prefix"], names
by_name = {s["name"]: s for s in serving["scenarios"]}
for s in serving["scenarios"]:
    for key in ("ttft_ns", "tpot_ns", "latency_ns", "queue_depth", "batch_occupancy"):
        assert key in s["metrics"], f"{s['name']}: missing {key}"
    c = s["metrics"]["counters"]
    for key in ("sched_iterations", "sched_queued", "sched_admitted",
                "sched_rejected", "prefill_waves", "decode_waves",
                "multi_session_decode_waves", "prefix_hits", "prefix_misses",
                "prefix_attached_pages", "cow_copies", "saved_prefill_cycles",
                "prog_cache_hits", "prog_cache_misses", "machines_allocated"):
        assert key in c, f"{s['name']}: missing counter {key}"
    assert c["sched_admitted"] == c["sched_queued"] - c["sched_rejected"], s["name"]
cont = by_name["continuous"]
assert cont["metrics"]["counters"]["multi_session_decode_waves"] >= 1, cont
assert cont["metrics"]["batch_occupancy"]["count"] >= 1, cont
pc = by_name["prefix"]["prefix_cache"]
assert pc["hits"] >= 1 and pc["misses"] == 1, pc
assert pc["hit_rate"] > 0.0, pc
assert pc["saved_prefill_cycles"] > 0, pc
sim = by_name["sim_attrib"]["metrics"]["counters"]
assert sim["prog_cache_hits"] >= 1, sim
assert sim["prog_cache_misses"] < sim["sim_dispatches"], sim
hot = json.load(open("BENCH_hotpath.json"))
modes = {m["name"]: m for m in hot["prog_cache_sweep"]["modes"]}
cached, uncached = modes["cached"], modes["uncached"]
assert cached["programs_built"] < cached["shards_executed"], cached
assert cached["prog_cache_hits"] >= 1, cached
assert uncached["prog_cache_hits"] == 0, uncached
assert uncached["programs_built"] >= uncached["shards_executed"], uncached
print("BENCH_serving.json + BENCH_hotpath.json: schema OK")
EOF
    else
        echo "== python3 not installed; skipping JSON validation =="
    fi
fi

echo "verify OK"
