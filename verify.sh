#!/bin/sh
# Tier-1 verify entrypoint (ROADMAP.md): release build, tests, rustdoc.
#
# Runs the same recipe the driver and CI use:
#   cargo build --release && cargo test -q && cargo doc --no-deps
#
# The rustdoc step is held to zero warnings (satellite requirement:
# the public API docs must stay clean).
set -eu
cd "$(dirname "$0")"

if ! command -v cargo >/dev/null 2>&1; then
    echo "verify: cargo not found on PATH." >&2
    echo "This image ships no Rust toolchain; run verify on a host with" >&2
    echo "rustc >= 1.75 (no network needed: all deps are vendored in-tree" >&2
    echo "under rust/vendor/, see DESIGN.md section 'substitutions')." >&2
    exit 2
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo doc --no-deps (warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "verify OK"
